//! Per-layer G allocation policies.
//!
//! The per-layer GAV parameter `G` (how many most-significant bit-serial
//! steps run at the guarded voltage) used to be a raw `Vec<u32>` smeared
//! across `ServeConfig`, `Executor` and `main.rs`; [`GavPolicy`] makes the
//! allocation strategy a first-class value that the
//! [`EngineBuilder`](super::EngineBuilder) resolves exactly once, at build
//! time. The ILP allocator (paper §IV-D) plugs in as
//! [`GavPolicy::IlpBudget`] instead of being a separate CLI code path.

use std::sync::Arc;

use crate::arch::ArchConfig;
use crate::dnn::{Executor, PlannedModel};
use crate::engine::backend::{FloatBackend, GavinaBackend};
use crate::engine::GavinaError;
use crate::errmodel::ErrorTables;
use crate::ilp::{Allocation, GavAllocator, LayerChoices};

/// How per-layer G values are chosen.
///
/// ```
/// use gavina::engine::GavPolicy;
///
/// // A uniform mid-range guard on every layer:
/// let p = GavPolicy::Uniform(3);
/// assert_eq!(p.describe(), "uniform G=3");
/// ```
#[derive(Clone, Debug, PartialEq)]
pub enum GavPolicy {
    /// Fully guarded: `G = G_max` on every layer (bit-exact operation).
    Exact,
    /// The same G on every layer (the Fig. 6 sweep axis).
    Uniform(u32),
    /// Explicit per-layer G values (length must equal the conv layer
    /// count).
    PerLayer(Vec<u32>),
    /// Optimal per-layer allocation under an op-weighted average-G budget
    /// (branch-and-bound ILP, paper §IV-D). Resolving this policy needs a
    /// profile set (see [`EngineBuilder::profile_set`]) and calibrated
    /// error tables.
    ///
    /// [`EngineBuilder::profile_set`]: super::EngineBuilder::profile_set
    IlpBudget {
        /// Target op-weighted average G (`G_tar` in the paper).
        gtar: f64,
    },
}

impl GavPolicy {
    /// One-line human description (serve banners, diagnostics).
    pub fn describe(&self) -> String {
        match self {
            GavPolicy::Exact => "exact (G=G_max everywhere)".into(),
            GavPolicy::Uniform(g) => format!("uniform G={g}"),
            GavPolicy::PerLayer(gs) => format!("per-layer G {gs:?}"),
            GavPolicy::IlpBudget { gtar } => format!("ILP allocation, G_tar={gtar}"),
        }
    }
}

/// The ILP resolution artifacts, kept on the engine so callers can print
/// the Fig. 8a profile and the achieved allocation without re-profiling.
#[derive(Clone, Debug)]
pub struct IlpReport {
    /// Per-layer option menus: `choices[l].cost[g]` is the logit MSE when
    /// only layer `l` runs at `G = g` on the profile set.
    pub choices: Vec<LayerChoices>,
    /// The solved allocation.
    pub allocation: Allocation,
}

/// Profile set for [`GavPolicy::IlpBudget`] resolution.
#[derive(Clone)]
pub(crate) struct ProfileSet {
    pub images: Vec<f32>,
    pub n: usize,
    pub batch: usize,
}

/// Per-layer perturbation profile (paper Fig. 8a): for every conv layer
/// and every `G`, the logit MSE versus the exact reference when only that
/// layer is undervolted. Layer `li` profiles at seed `seed + li` — the
/// historical `allocate` subcommand seeding.
///
/// Profiling runs over the **compiled** model: the weights were packed
/// once at lowering, and each `(layer, G)` point only re-resolves the
/// schedules (`PlannedModel::with_layer_gs` shares the packed planes).
pub(crate) fn profile_layer_choices(
    model: &PlannedModel,
    arch: &ArchConfig,
    tables: &Arc<ErrorTables>,
    seed: u64,
    set: &ProfileSet,
) -> Result<Vec<LayerChoices>, GavinaError> {
    if set.images.len() != set.n * crate::dnn::IMAGE_LEN {
        return Err(GavinaError::Shape {
            what: format!("profile set (n={})", set.n),
            expected: set.n * crate::dnn::IMAGE_LEN,
            got: set.images.len(),
        });
    }
    let prec = model.prec();
    let n_layers = model.plans().len();
    let exact_gs = vec![prec.max_g(); n_layers];
    let base = model.with_layer_gs(&exact_gs);
    let ref_out =
        Executor::planned(&base, &FloatBackend).forward_batched(&set.images, set.n, set.batch);
    let mut layers = Vec::with_capacity(n_layers);
    for li in 0..n_layers {
        let mut cost = vec![0.0f64; (prec.max_g() + 1) as usize];
        let mut macs = 1u64;
        for g in 0..prec.max_g() {
            let backend = GavinaBackend {
                arch: arch.clone(),
                tables: Some(Arc::clone(tables)),
                seed: seed + li as u64,
            };
            let mut gs = exact_gs.clone();
            gs[li] = g;
            let probe = base.with_layer_gs(&gs);
            let out =
                Executor::planned(&probe, &backend).forward_batched(&set.images, set.n, set.batch);
            macs = out.stats.layer_macs[li].max(1);
            cost[g as usize] = crate::stats::mse_f32(&ref_out.logits, &out.logits);
        }
        layers.push(LayerChoices {
            ops: macs as f64,
            cost,
        });
    }
    Ok(layers)
}

/// Resolve a policy into the per-layer G vector (and, for the ILP, its
/// report). Pure validation for the first three variants; `IlpBudget`
/// profiles (over the compiled model) and solves.
pub(crate) fn resolve(
    policy: &GavPolicy,
    model: &PlannedModel,
    arch: &ArchConfig,
    tables: Option<&Arc<ErrorTables>>,
    seed: u64,
    profile: Option<&ProfileSet>,
) -> Result<(Vec<u32>, Option<IlpReport>), GavinaError> {
    let prec = model.prec();
    let n_layers = model.plans().len();
    let max_g = prec.max_g();
    match policy {
        GavPolicy::Exact => Ok((vec![max_g; n_layers], None)),
        GavPolicy::Uniform(g) => {
            if *g > max_g {
                return Err(GavinaError::Config(format!(
                    "uniform G={g} exceeds G_max={max_g} for {prec}"
                )));
            }
            Ok((vec![*g; n_layers], None))
        }
        GavPolicy::PerLayer(gs) => {
            if gs.len() != n_layers {
                return Err(GavinaError::Shape {
                    what: "per-layer G vector".into(),
                    expected: n_layers,
                    got: gs.len(),
                });
            }
            if let Some(bad) = gs.iter().find(|&&g| g > max_g) {
                return Err(GavinaError::Config(format!(
                    "per-layer G={bad} exceeds G_max={max_g} for {prec}"
                )));
            }
            Ok((gs.clone(), None))
        }
        GavPolicy::IlpBudget { gtar } => {
            if gtar.is_nan() || *gtar < 0.0 {
                return Err(GavinaError::Config(format!(
                    "ILP budget G_tar={gtar} must be non-negative"
                )));
            }
            let tables = tables.ok_or_else(|| {
                GavinaError::Config(
                    "GavPolicy::IlpBudget needs calibrated error tables \
                     (EngineBuilder::tables)"
                        .into(),
                )
            })?;
            let set = profile.ok_or_else(|| {
                GavinaError::Config(
                    "GavPolicy::IlpBudget needs a profile set \
                     (EngineBuilder::profile_set)"
                        .into(),
                )
            })?;
            let choices = profile_layer_choices(model, arch, tables, seed, set)?;
            let allocation = GavAllocator::new(choices.clone()).solve(*gtar);
            let gs = allocation.gs.clone();
            Ok((
                gs,
                Some(IlpReport {
                    choices,
                    allocation,
                }),
            ))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::Precision;
    use crate::dnn::conv_layer_names;
    use crate::dnn::exec::synth::synthetic_weights;

    fn ctx() -> (PlannedModel, Precision, ArchConfig) {
        let prec = Precision::new(2, 2);
        let weights = synthetic_weights(0.125, 1);
        let gs = vec![prec.max_g(); conv_layer_names().len()];
        (
            PlannedModel::lower(&weights, 0.125, prec, &gs),
            prec,
            ArchConfig::tiny(),
        )
    }

    #[test]
    fn exact_uniform_per_layer_resolve_without_profiling() {
        let (m, prec, arch) = ctx();
        let n = conv_layer_names().len();
        let (gs, rep) = resolve(&GavPolicy::Exact, &m, &arch, None, 1, None).unwrap();
        assert_eq!(gs, vec![prec.max_g(); n]);
        assert!(rep.is_none());

        let (gs, _) = resolve(&GavPolicy::Uniform(1), &m, &arch, None, 1, None).unwrap();
        assert_eq!(gs, vec![1; n]);

        let want: Vec<u32> = (0..n as u32).map(|i| i % (prec.max_g() + 1)).collect();
        let (gs, _) = resolve(&GavPolicy::PerLayer(want.clone()), &m, &arch, None, 1, None)
            .unwrap();
        assert_eq!(gs, want);
    }

    #[test]
    fn invalid_policies_are_config_errors() {
        let (m, prec, arch) = ctx();
        let too_big = GavPolicy::Uniform(prec.max_g() + 1);
        assert!(matches!(
            resolve(&too_big, &m, &arch, None, 1, None),
            Err(GavinaError::Config(_))
        ));
        let short = GavPolicy::PerLayer(vec![0; 3]);
        assert!(matches!(
            resolve(&short, &m, &arch, None, 1, None),
            Err(GavinaError::Shape { .. })
        ));
        let no_tables = GavPolicy::IlpBudget { gtar: 1.0 };
        assert!(matches!(
            resolve(&no_tables, &m, &arch, None, 1, None),
            Err(GavinaError::Config(_))
        ));
    }
}
