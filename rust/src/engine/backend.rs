//! Pluggable execution backends for the quantized-network executor.
//!
//! The old `dnn::Backend<'a>` enum hard-wired the three execution modes
//! into every call site; [`ExecBackend`] turns the seam into a trait so
//! new backends (remote accelerators, fault-injection campaigns, …) plug
//! in without touching `dnn/exec.rs`. Three implementations ship:
//!
//! * [`FloatBackend`] — the exact fake-quant reference (integer GEMM in
//!   i64, no hardware model); the "exact result" the paper measures
//!   perturbation against.
//! * [`GavinaBackend`] — the cycle-level GAVINA simulator with optional
//!   LUT error injection (paper §IV-C).
//! * [`GlsBackend`] — cycle-level simulation with every undervolted tile
//!   run through full gate-level simulation (paper Fig. 5 methodology).
//!
//! Since the compile-once refactor a backend consumes **pre-packed
//! bit-planes** only: [`LayerGemm`] carries the activation planes (packed
//! once per layer per request, directly in the plane-interleaved layout
//! the fused exact kernel consumes) and a [`LayerPlan`] whose weight
//! planes were packed exactly once at `EngineBuilder::build()` in both
//! layouts. No backend quantizes or bit-plane-packs anything per request;
//! the simulator backends re-lay the activation planes plane-major once
//! per GEMM (their step-sequence tile carving needs that form — a linear
//! pass, negligible against cycle-level simulation).
//!
//! Determinism contract: a backend must derive all randomness from
//! `(its own seed, job.stream, job.plan.layer_idx())` so that identical
//! jobs produce identical results on any thread.

use std::sync::Arc;

use crate::arch::ArchConfig;
use crate::dnn::plan::LayerPlan;
use crate::errmodel::ErrorTables;
use crate::gls::GlsContext;
use crate::quant::InterleavedPlanes;
use crate::simulator::GavinaSim;

/// One convolution-lowered integer GEMM, as handed to a backend: packed
/// activation planes × a compiled layer plan.
pub struct LayerGemm<'a> {
    /// Activation bit-planes `[C, L]` (im2col output, quantized and
    /// packed once per layer by the executor — plane-interleaved, the
    /// fused kernel's layout).
    pub a: &'a InterleavedPlanes,
    /// The compiled layer: weight bit-planes `[K, C]` packed at
    /// `build()`, the resolved [`GavSchedule`](crate::arch::GavSchedule)
    /// for the layer's G, and the layer index that seeds the per-layer
    /// RNG stream.
    pub plan: &'a LayerPlan,
    /// Deterministic sub-batch stream id (serving shards); `0` for
    /// standalone runs. XOR-mixed into the backend seed.
    pub stream: u64,
}

/// Hardware counters reported by one backend GEMM.
#[derive(Clone, Copy, Debug, Default)]
pub struct GemmCounters {
    pub cycles: u64,
    pub tiles: u64,
    pub corrupted: u64,
    pub executed_macs: u64,
    /// Significance steps executed undervolted (error injection armed).
    pub steps_approx: u64,
    /// Significance steps executed guarded (always exact).
    pub steps_guarded: u64,
}

/// A backend GEMM result: the `[K, L]` product plus counters.
pub struct BackendGemm {
    /// Product `[K, L]` row-major, i64 accumulators.
    pub p: Vec<i64>,
    pub counters: GemmCounters,
}

/// A pluggable execution backend for conv-lowered integer GEMMs.
///
/// Implementations must be `Send + Sync`: one backend instance is shared
/// (behind an `Arc`) by every serving worker and intra-batch thread.
pub trait ExecBackend: Send + Sync {
    /// Short display name (diagnostics, serve banners).
    fn name(&self) -> &'static str;

    /// Execute one layer GEMM deterministically.
    fn run_layer_gemm(&self, job: &LayerGemm) -> BackendGemm;

    /// Whether the backend models accelerator hardware (cycle/energy
    /// counters are meaningful). The float reference returns `false`.
    fn is_simulated(&self) -> bool {
        true
    }
}

/// Per-layer RNG stream derivation shared by the simulator backends: the
/// historical `Executor` seeding (`seed.wrapping_add(layer · 0x9E37)`)
/// with the serving shard stream XOR-mixed in first, so results are
/// bit-identical to the pre-trait code on both the standalone and the
/// serving path.
fn layer_seed(seed: u64, job: &LayerGemm) -> u64 {
    (seed ^ job.stream).wrapping_add(job.plan.layer_idx() as u64 * 0x9E37)
}

/// Exact fake-quant reference (no hardware model). Runs the fused
/// plane-interleaved bit-serial kernel — one pass over memory — which is
/// exactly equal to the plain integer GEMM
/// (`gemm::kernel::fused_gemm == gemm::gemm_exact`, property-tested in
/// [`crate::gemm::kernel`]). Both operands already arrive in the fused
/// kernel's layout: nothing is converted, packed or copied here.
#[derive(Clone, Copy, Debug, Default)]
pub struct FloatBackend;

impl ExecBackend for FloatBackend {
    fn name(&self) -> &'static str {
        "float"
    }

    fn run_layer_gemm(&self, job: &LayerGemm) -> BackendGemm {
        BackendGemm {
            p: crate::gemm::kernel::fused_gemm(job.a, job.plan.interleaved_b()),
            counters: GemmCounters::default(),
        }
    }

    fn is_simulated(&self) -> bool {
        false
    }
}

/// Cycle-level GAVINA simulator with optional LUT error injection.
#[derive(Clone)]
pub struct GavinaBackend {
    pub arch: ArchConfig,
    /// GLS-calibrated error tables; `None` disables injection (guarded
    /// runs stay exact either way).
    pub tables: Option<Arc<ErrorTables>>,
    pub seed: u64,
}

impl ExecBackend for GavinaBackend {
    fn name(&self) -> &'static str {
        "gavina-sim"
    }

    fn run_layer_gemm(&self, job: &LayerGemm) -> BackendGemm {
        let mut sim = GavinaSim::new(
            self.arch.clone(),
            self.tables.as_deref(),
            layer_seed(self.seed, job),
        );
        // The simulator carves step-sequence tiles out of plane-major
        // operands; re-lay the activation planes once (bit-identical to
        // packing them plane-major in the first place).
        let pa = job.a.to_packed();
        let rep = sim.run_planes(&pa, job.plan.packed_b(), job.plan.sched());
        BackendGemm {
            p: rep.p,
            counters: GemmCounters {
                cycles: rep.cycles,
                tiles: rep.n_tiles,
                corrupted: rep.values_corrupted,
                executed_macs: rep.executed_macs,
                steps_approx: rep.steps_approx,
                steps_guarded: rep.steps_guarded,
            },
        }
    }
}

/// Cycle-level simulation with full gate-level simulation of every
/// undervolted tile (very slow; Fig. 5/7 methodology at network scale).
#[derive(Clone)]
pub struct GlsBackend {
    pub arch: ArchConfig,
    pub ctx: Arc<GlsContext>,
    pub seed: u64,
}

impl ExecBackend for GlsBackend {
    fn name(&self) -> &'static str {
        "gavina-gls"
    }

    fn run_layer_gemm(&self, job: &LayerGemm) -> BackendGemm {
        let mut sim = GavinaSim::new_gls(self.arch.clone(), &self.ctx, layer_seed(self.seed, job));
        let pa = job.a.to_packed();
        let rep = sim.run_planes(&pa, job.plan.packed_b(), job.plan.sched());
        BackendGemm {
            p: rep.p,
            counters: GemmCounters {
                cycles: rep.cycles,
                tiles: rep.n_tiles,
                corrupted: rep.values_corrupted,
                executed_macs: rep.executed_macs,
                steps_approx: rep.steps_approx,
                steps_guarded: rep.steps_guarded,
            },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::{GavSchedule, Precision};
    use crate::util::Prng;
    use crate::workload::uniform_ip_matrices;

    fn packed_job(
        a: &[i32],
        b: &[i32],
        c: usize,
        l: usize,
        k: usize,
        prec: Precision,
        layer_idx: usize,
    ) -> (InterleavedPlanes, LayerPlan) {
        (
            InterleavedPlanes::from_a_matrix(a, c, l, prec.a_bits),
            LayerPlan::for_gemm(b, k, c, GavSchedule::all_guarded(prec), layer_idx),
        )
    }

    #[test]
    fn float_and_guarded_sim_agree_at_backend_level() {
        let arch = ArchConfig::tiny();
        let prec = Precision::new(4, 4);
        let mut rng = Prng::new(1);
        let (c, l, k) = (arch.c_dim, arch.l_dim, arch.k_dim);
        let (a, b) = uniform_ip_matrices(c, l, k, prec, &mut rng);
        let (pa, plan) = packed_job(&a, &b, c, l, k, prec, 3);
        let job = LayerGemm {
            a: &pa,
            plan: &plan,
            stream: 0,
        };

        let exact = FloatBackend.run_layer_gemm(&job);
        assert_eq!(exact.counters.cycles, 0);
        assert!(!FloatBackend.is_simulated());
        // The float backend's packed popcount path equals the plain
        // integer GEMM bit for bit.
        assert_eq!(exact.p, crate::gemm::gemm_exact(&a, &b, c, l, k));

        let sim = GavinaBackend {
            arch,
            tables: None,
            seed: 2,
        };
        let guarded = sim.run_layer_gemm(&job);
        assert_eq!(exact.p, guarded.p);
        assert!(guarded.counters.cycles > 0);
        assert_eq!(guarded.counters.corrupted, 0);
        // All-guarded schedule: every step is guarded, none undervolted.
        assert!(guarded.counters.steps_guarded > 0);
        assert_eq!(guarded.counters.steps_approx, 0);
    }

    #[test]
    fn stream_and_layer_perturb_the_seed_deterministically() {
        // Same (seed, stream, layer) => identical; different stream =>
        // the derived seed differs (the serving-shard contract).
        let prec = Precision::new(2, 2);
        let pa = InterleavedPlanes::from_a_matrix(&[0], 1, 1, prec.a_bits);
        let plan = LayerPlan::for_gemm(&[0], 1, 1, GavSchedule::all_guarded(prec), 5);
        assert_eq!(
            layer_seed(
                7,
                &LayerGemm {
                    a: &pa,
                    plan: &plan,
                    stream: 0,
                }
            ),
            7u64.wrapping_add(5 * 0x9E37)
        );
        assert_eq!(
            layer_seed(
                7,
                &LayerGemm {
                    a: &pa,
                    plan: &plan,
                    stream: 0xD1F,
                }
            ),
            (7u64 ^ 0xD1F).wrapping_add(5 * 0x9E37)
        );
    }
}
