//! The crate-wide error type for the [`Engine`](super::Engine) API.
//!
//! Every fallible entry point of the public facade — builder validation,
//! artifact loading, request shapes, backend execution — returns
//! [`GavinaError`] instead of panicking, so a malformed request yields an
//! error `Response` while the serving workers keep running.

/// Typed error for the `gavina::engine` and `gavina::serve` public APIs.
///
/// The variants mirror the ways the facade can fail: a configuration that
/// cannot produce a valid engine, an artifact that cannot be read, a
/// tensor/request with the wrong shape, a backend execution failure, and
/// the serving-control outcomes (admission rejection, cancellation,
/// missed deadlines) that a [`crate::serve::Session`] reports per ticket.
///
/// ```
/// use gavina::engine::GavinaError;
///
/// let e = GavinaError::Shape {
///     what: "request image".into(),
///     expected: 3072,
///     got: 100,
/// };
/// assert_eq!(
///     e.to_string(),
///     "shape error: request image: expected 3072, got 100"
/// );
/// ```
#[derive(Clone, Debug)]
pub enum GavinaError {
    /// Invalid or inconsistent configuration (builder validation, config
    /// file sections, policy/backend mismatches).
    Config(String),
    /// An artifact (weights, error tables, eval set) could not be read.
    Io {
        /// Path of the artifact that failed to load.
        path: String,
        /// The underlying I/O error, stringified (keeps the type `Clone`).
        message: String,
    },
    /// A tensor or request had the wrong number of elements.
    Shape {
        /// What was being checked (e.g. `"request image"`).
        what: String,
        /// Expected element count.
        expected: usize,
        /// Actual element count.
        got: usize,
    },
    /// A backend failed to execute (reserved for pluggable backends; the
    /// built-in simulators are total).
    Backend(String),
    /// The serving admission queue is full: `capacity` requests are
    /// already in flight. The service stays up — back off and retry.
    Overloaded {
        /// The admission-queue depth that was exhausted.
        capacity: usize,
    },
    /// The request was cancelled via
    /// [`Ticket::cancel`](crate::serve::Ticket::cancel) before it
    /// executed.
    Cancelled,
    /// A request exceeded its submission deadline before executing —
    /// the service's terminal verdict on that request (a local
    /// [`Ticket::wait_timeout`](crate::serve::Ticket::wait_timeout)
    /// poll expiring is `Ok(None)`, not this).
    DeadlineExceeded {
        /// How long the request had waited when the deadline fired [ms].
        waited_ms: u64,
    },
}

impl GavinaError {
    /// Wrap an `std::io::Error` with the path it occurred on.
    pub fn io(path: impl AsRef<std::path::Path>, err: std::io::Error) -> Self {
        GavinaError::Io {
            path: path.as_ref().display().to_string(),
            message: err.to_string(),
        }
    }
}

impl std::fmt::Display for GavinaError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            GavinaError::Config(msg) => write!(f, "config error: {msg}"),
            GavinaError::Io { path, message } => write!(f, "io error at {path}: {message}"),
            GavinaError::Shape {
                what,
                expected,
                got,
            } => write!(f, "shape error: {what}: expected {expected}, got {got}"),
            GavinaError::Backend(msg) => write!(f, "backend error: {msg}"),
            GavinaError::Overloaded { capacity } => write!(
                f,
                "service overloaded: {capacity} requests already in flight"
            ),
            GavinaError::Cancelled => write!(f, "request cancelled"),
            GavinaError::DeadlineExceeded { waited_ms } => {
                write!(f, "deadline exceeded after {waited_ms} ms")
            }
        }
    }
}

impl std::error::Error for GavinaError {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_covers_all_variants() {
        let cases: Vec<(GavinaError, &str)> = vec![
            (GavinaError::Config("bad g".into()), "config error: bad g"),
            (
                GavinaError::io("/nope/weights.bin", std::io::Error::other("gone")),
                "io error at /nope/weights.bin: gone",
            ),
            (
                GavinaError::Shape {
                    what: "image".into(),
                    expected: 4,
                    got: 3,
                },
                "shape error: image: expected 4, got 3",
            ),
            (
                GavinaError::Backend("sim died".into()),
                "backend error: sim died",
            ),
            (
                GavinaError::Overloaded { capacity: 64 },
                "service overloaded: 64 requests already in flight",
            ),
            (GavinaError::Cancelled, "request cancelled"),
            (
                GavinaError::DeadlineExceeded { waited_ms: 15 },
                "deadline exceeded after 15 ms",
            ),
        ];
        for (e, want) in cases {
            assert_eq!(e.to_string(), want);
        }
    }

    #[test]
    fn is_std_error_and_clone() {
        let e = GavinaError::Config("x".into());
        let boxed: Box<dyn std::error::Error> = Box::new(e.clone());
        assert!(boxed.to_string().contains("x"));
    }
}
