//! The crate's public inference API: a validated, `Arc`-shareable
//! [`Engine`] built once by [`EngineBuilder`].
//!
//! Every entry point — CLI subcommands, examples, benches, the serving
//! layer — used to hand-assemble `Executor::new(weights, …)` and
//! mutate its public `layer_gs` field; the engine facade replaces that
//! borrow-laden, panic-on-misuse surface with four pieces:
//!
//! * [`EngineBuilder`] — weights, [`Precision`], [`ArchConfig`], error
//!   tables, seed, threads; validates everything once in
//!   [`EngineBuilder::build`] and never after. `build()` also **compiles
//!   the data plane**: the network is lowered into per-layer
//!   [`LayerPlan`](crate::dnn::LayerPlan)s (weights quantized and packed
//!   as bit-planes, BN folded, GAV schedules resolved) exactly once, so
//!   requests only pay for activation work.
//! * [`GavPolicy`] — first-class per-layer G allocation (`Exact`,
//!   `Uniform`, `PerLayer`, or the §IV-D ILP under a budget).
//! * [`ExecBackend`] — pluggable execution backends (float reference,
//!   cycle-level simulator, gate-level simulation) instead of the old
//!   lifetime-bearing `Backend<'a>` enum.
//! * [`GavinaError`] — typed errors on every fallible path; a malformed
//!   request yields an error `Response`, not a dead worker thread.
//!
//! ```
//! use gavina::arch::{ArchConfig, Precision};
//! use gavina::dnn::exec::synth::synthetic_weights;
//! use gavina::engine::{EngineBuilder, GavPolicy};
//!
//! let engine = EngineBuilder::new()
//!     .weights(synthetic_weights(0.125, 1))
//!     .width_mult(0.125)
//!     .precision(Precision::new(2, 2))
//!     .arch(ArchConfig::tiny())
//!     .policy(GavPolicy::Exact)
//!     .build()
//!     .unwrap();
//! let image = vec![0.5f32; 32 * 32 * 3];
//! let out = engine.infer(&image, 1).unwrap();
//! assert_eq!(out.logits.len(), out.classes);
//! ```

pub mod backend;
mod error;
mod policy;

use std::sync::Arc;

use crate::arch::{ArchConfig, GavSchedule, Precision};
use crate::config::{Config, Value};
use crate::dnn::exec::{ch, synth, BLOCKS_PER_STAGE, STAGES};
use crate::dnn::weights::AnyTensor;
use crate::dnn::{
    conv_layer_names, Executor, ForwardResult, ForwardStats, PlannedModel, TensorMap, IMAGE_LEN,
};
use crate::errmodel::ErrorTables;
use crate::gls::GlsContext;
use crate::ilp::{Allocation, GavAllocator, LayerChoices};
use crate::serve::{ServeOptions, Service};
use crate::util::parallel;

pub use backend::{ExecBackend, FloatBackend, GavinaBackend, GlsBackend};
pub use error::GavinaError;
pub use policy::{GavPolicy, IlpReport};

use policy::ProfileSet;

/// Which backend [`EngineBuilder::build`] instantiates.
#[derive(Clone)]
enum BackendChoice {
    /// Exact fake-quant reference (no hardware model).
    Float,
    /// Cycle-level GAVINA simulator (default; error injection when tables
    /// are present).
    Gavina,
    /// Gate-level simulation of every undervolted tile (very slow).
    Gls(Arc<GlsContext>),
    /// A user-supplied backend.
    Custom(Arc<dyn ExecBackend>),
}

/// Builder for [`Engine`]: collect configuration, validate once, produce
/// an immutable engine. See the [module docs](self) for a quickstart.
#[derive(Clone)]
pub struct EngineBuilder {
    weights: Option<Arc<TensorMap>>,
    width_mult: f64,
    prec: Precision,
    arch: ArchConfig,
    tables: Option<Arc<ErrorTables>>,
    backend: BackendChoice,
    policy: GavPolicy,
    /// Whether `policy` was set explicitly (via [`EngineBuilder::policy`]
    /// or a named `engine.policy` config key) — bare-key inference in
    /// [`EngineBuilder::apply_config`] never overrides an explicit choice.
    policy_explicit: bool,
    seed: u64,
    threads: usize,
    profile: Option<ProfileSet>,
}

impl Default for EngineBuilder {
    fn default() -> Self {
        Self::new()
    }
}

impl EngineBuilder {
    pub fn new() -> Self {
        Self {
            weights: None,
            width_mult: 0.25,
            prec: Precision::new(4, 4),
            arch: ArchConfig::paper(),
            tables: None,
            backend: BackendChoice::Gavina,
            policy: GavPolicy::Exact,
            policy_explicit: false,
            seed: 2025,
            threads: 1,
            profile: None,
        }
    }

    /// Set the weight map (accepts `TensorMap` or `Arc<TensorMap>`).
    pub fn weights(mut self, weights: impl Into<Arc<TensorMap>>) -> Self {
        self.weights = Some(weights.into());
        self
    }

    /// Load weights from a GVNT file ([`crate::dnn::load_tensors`]).
    pub fn weights_from_file(self, path: &std::path::Path) -> Result<Self, GavinaError> {
        let w = crate::dnn::load_tensors(path).map_err(|e| GavinaError::io(path, e))?;
        Ok(self.weights(w))
    }

    /// Random-but-valid synthetic weights (tests / demos without
    /// `make artifacts`); also sets the matching `width_mult`.
    pub fn synthetic_weights(mut self, width_mult: f64, seed: u64) -> Self {
        self.width_mult = width_mult;
        self.weights(synth::synthetic_weights(width_mult, seed))
    }

    /// ResNet width multiplier (must match the trained weights).
    pub fn width_mult(mut self, width_mult: f64) -> Self {
        self.width_mult = width_mult;
        self
    }

    /// `aXwY` activation/weight precision.
    pub fn precision(mut self, prec: Precision) -> Self {
        self.prec = prec;
        self
    }

    /// Architectural parameters (array dims, voltages, clock).
    pub fn arch(mut self, arch: ArchConfig) -> Self {
        self.arch = arch;
        self
    }

    /// GLS-calibrated error tables for undervolting injection.
    pub fn tables(mut self, tables: impl Into<Arc<ErrorTables>>) -> Self {
        self.tables = Some(tables.into());
        self
    }

    /// Optional error tables (convenience for call sites that may or may
    /// not have calibrated artifacts).
    pub fn tables_opt(mut self, tables: Option<Arc<ErrorTables>>) -> Self {
        self.tables = tables;
        self
    }

    /// Per-layer G allocation policy (default [`GavPolicy::Exact`]).
    pub fn policy(mut self, policy: GavPolicy) -> Self {
        self.policy = policy;
        self.policy_explicit = true;
        self
    }

    /// The currently configured policy (what [`EngineBuilder::build`]
    /// will resolve) — lets callers branch on the outcome of
    /// [`EngineBuilder::apply_config`], e.g. to attach a profile set
    /// only when the config selected [`GavPolicy::IlpBudget`].
    pub fn policy_ref(&self) -> &GavPolicy {
        &self.policy
    }

    /// Deterministic seed for error injection (default 2025).
    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Intra-batch worker threads for [`Engine::infer_parallel`] and the
    /// serving layer (`1` = serial, `0` = one per core). The
    /// single-executor entry points ([`Engine::infer_shard`],
    /// [`Engine::infer_rows`]) spend the same budget inside the fused
    /// activation prologue instead — bit-identical either way.
    pub fn threads(mut self, threads: usize) -> Self {
        self.threads = threads;
        self
    }

    /// Use the exact fake-quant reference backend (no hardware model).
    pub fn backend_float(mut self) -> Self {
        self.backend = BackendChoice::Float;
        self
    }

    /// Use the cycle-level GAVINA simulator (the default).
    pub fn backend_gavina(mut self) -> Self {
        self.backend = BackendChoice::Gavina;
        self
    }

    /// Run every undervolted tile through full gate-level simulation.
    pub fn backend_gls(mut self, ctx: impl Into<Arc<GlsContext>>) -> Self {
        self.backend = BackendChoice::Gls(ctx.into());
        self
    }

    /// Plug in a custom [`ExecBackend`] implementation.
    pub fn backend(mut self, backend: Arc<dyn ExecBackend>) -> Self {
        self.backend = BackendChoice::Custom(backend);
        self
    }

    /// Profile set used to resolve [`GavPolicy::IlpBudget`]: `n` images
    /// (flat NHWC, `n · 3072` floats) forwarded in mini-batches of
    /// `batch` during per-layer sensitivity profiling. An empty set
    /// clears the profile (an `IlpBudget` build will then fail with a
    /// config error instead of profiling on nothing).
    pub fn profile_set(mut self, images: &[f32], n: usize, batch: usize) -> Self {
        self.profile = if n == 0 {
            None
        } else {
            Some(ProfileSet {
                images: images.to_vec(),
                n,
                batch: batch.max(1),
            })
        };
        self
    }

    /// Apply the `[engine]` section of a parsed config file. Recognized
    /// keys: `precision`, `policy` (`"exact"`, `"uniform"`, `"per_layer"`,
    /// `"ilp"`), `g`, `gtar`, `layer_gs`, `width_mult`, `threads`,
    /// `seed`. Unknown `engine.*` keys are a [`GavinaError::Config`] —
    /// typos must not silently fall back to defaults.
    pub fn apply_config(mut self, cfg: &Config) -> Result<Self, GavinaError> {
        const KNOWN: &[&str] = &[
            "precision",
            "policy",
            "g",
            "gtar",
            "layer_gs",
            "width_mult",
            "threads",
            "seed",
        ];
        for (key, _) in cfg.keys_with_prefix("engine.") {
            if !KNOWN.contains(&key) {
                return Err(GavinaError::Config(format!(
                    "unknown [engine] key '{key}' (known: {})",
                    KNOWN.join(", ")
                )));
            }
        }
        if let Some(v) = cfg.get("engine.precision") {
            let s = v.as_str().unwrap_or_default();
            self.prec = Precision::parse(s).ok_or_else(|| {
                GavinaError::Config(format!("engine.precision '{s}' is not aXwY"))
            })?;
        }
        if let Some(v) = cfg.get("engine.width_mult") {
            self.width_mult = v.as_float().ok_or_else(|| {
                GavinaError::Config("engine.width_mult must be a number".into())
            })?;
        }
        if let Some(v) = cfg.get("engine.threads") {
            self.threads = v
                .as_int()
                .and_then(|i| usize::try_from(i).ok())
                .ok_or_else(|| {
                    GavinaError::Config("engine.threads must be a non-negative integer".into())
                })?;
        }
        if let Some(v) = cfg.get("engine.seed") {
            self.seed = v
                .as_int()
                .and_then(|i| u64::try_from(i).ok())
                .ok_or_else(|| {
                    GavinaError::Config("engine.seed must be a non-negative integer".into())
                })?;
        }
        // `engine.g` with a legacy `run.g` fallback, mirroring
        // RunConfig::from_config — `policy = "uniform"` must work for a
        // config that still keeps its g under `[run]`.
        let g = match cfg.get("engine.g").or_else(|| cfg.get("run.g")) {
            Some(v) => Some(v.as_int().and_then(|i| u32::try_from(i).ok()).ok_or_else(
                || GavinaError::Config("engine.g must be a non-negative integer".into()),
            )?),
            None => None,
        };
        // Type-check `engine.gtar` up front: a quoted number must error,
        // not silently drop the ILP request.
        let gtar_cfg = match cfg.get("engine.gtar") {
            Some(v) => Some(v.as_float().ok_or_else(|| {
                GavinaError::Config("engine.gtar must be a number".into())
            })?),
            None => None,
        };
        let policy_name = cfg.get("engine.policy").map(|v| {
            v.as_str().map(str::to_string).ok_or_else(|| {
                GavinaError::Config("engine.policy must be a string".into())
            })
        });
        let policy_name = match policy_name {
            Some(r) => Some(r?),
            None => None,
        };
        match policy_name.as_deref() {
            Some("exact") => {
                self.policy = GavPolicy::Exact;
                self.policy_explicit = true;
            }
            Some("uniform") => {
                let g = g.ok_or_else(|| {
                    GavinaError::Config("engine.policy = \"uniform\" needs engine.g".into())
                })?;
                self.policy = GavPolicy::Uniform(g);
                self.policy_explicit = true;
            }
            Some("per_layer") => {
                let gs = cfg
                    .get("engine.layer_gs")
                    .and_then(|v| match v {
                        Value::Array(xs) => xs
                            .iter()
                            .map(|x| x.as_int().and_then(|i| u32::try_from(i).ok()))
                            .collect::<Option<Vec<u32>>>(),
                        _ => None,
                    })
                    .ok_or_else(|| {
                        GavinaError::Config(
                            "engine.policy = \"per_layer\" needs engine.layer_gs = [..]".into(),
                        )
                    })?;
                self.policy = GavPolicy::PerLayer(gs);
                self.policy_explicit = true;
            }
            Some("ilp") => {
                let gtar = gtar_cfg.ok_or_else(|| {
                    GavinaError::Config("engine.policy = \"ilp\" needs engine.gtar".into())
                })?;
                self.policy = GavPolicy::IlpBudget { gtar };
                self.policy_explicit = true;
            }
            Some(other) => {
                return Err(GavinaError::Config(format!(
                    "engine.policy '{other}' (want exact|uniform|per_layer|ilp)"
                )))
            }
            // No explicit policy key: infer from bare keys — `g` means
            // uniform G, `gtar` means the ILP budget, both at once is
            // ambiguous, and an explicit `engine.gtar` outranks a legacy
            // `[run] g`. Inference never overrides a policy the caller
            // set explicitly via [`EngineBuilder::policy`].
            None => {
                if cfg.get("engine.g").is_some() && gtar_cfg.is_some() {
                    return Err(GavinaError::Config(
                        "both engine.g and engine.gtar set without engine.policy — \
                         pick one (or set engine.policy explicitly)"
                            .into(),
                    ));
                }
                if !self.policy_explicit {
                    if let Some(gtar) = gtar_cfg {
                        self.policy = GavPolicy::IlpBudget { gtar };
                    } else if let Some(g) = g {
                        self.policy = GavPolicy::Uniform(g);
                    }
                }
            }
        }
        // A G knob that the chosen policy would silently drop is exactly
        // the typo class this loader exists to reject. (The legacy
        // `run.g` fallback is exempt — old configs carry it harmlessly.)
        if let Some(name) = policy_name.as_deref() {
            if cfg.get("engine.g").is_some() && name != "uniform" {
                return Err(GavinaError::Config(format!(
                    "engine.g is set but engine.policy = \"{name}\" ignores it"
                )));
            }
            if gtar_cfg.is_some() && name != "ilp" {
                return Err(GavinaError::Config(format!(
                    "engine.gtar is set but engine.policy = \"{name}\" ignores it"
                )));
            }
        }
        if cfg.get("engine.layer_gs").is_some()
            && !matches!(self.policy, GavPolicy::PerLayer(_))
        {
            return Err(GavinaError::Config(
                "engine.layer_gs is set but engine.policy is not \"per_layer\" — \
                 the allocation would be ignored"
                    .into(),
            ));
        }
        Ok(self)
    }

    /// Validate everything and produce an immutable [`Engine`].
    pub fn build(self) -> Result<Engine, GavinaError> {
        let weights = self
            .weights
            .ok_or_else(|| GavinaError::Config("EngineBuilder: weights not set".into()))?;
        if !self.width_mult.is_finite() || self.width_mult <= 0.0 {
            return Err(GavinaError::Config(format!(
                "width_mult {} must be positive",
                self.width_mult
            )));
        }
        validate_weights(&weights, self.width_mult)?;
        if matches!(self.backend, BackendChoice::Float)
            && matches!(self.policy, GavPolicy::IlpBudget { .. })
        {
            return Err(GavinaError::Config(
                "GavPolicy::IlpBudget profiles undervolting errors; it cannot \
                 resolve on the float reference backend"
                    .into(),
            ));
        }
        // Compile-once lowering: quantize + bit-plane-pack the weights
        // and fold BN exactly once, here. Policy resolution (including
        // ILP profiling) then runs over the compiled model, and the
        // chosen per-layer Gs only re-resolve the schedules — the packed
        // planes are shared, never re-packed.
        let max_gs = vec![self.prec.max_g(); conv_layer_names().len()];
        let base = PlannedModel::lower(&weights, self.width_mult, self.prec, &max_gs);
        let (layer_gs, ilp) = policy::resolve(
            &self.policy,
            &base,
            &self.arch,
            self.tables.as_ref(),
            self.seed,
            self.profile.as_ref(),
        )?;
        let model = Arc::new(base.with_layer_gs(&layer_gs));
        let backend: Arc<dyn ExecBackend> = match self.backend {
            BackendChoice::Float => Arc::new(FloatBackend),
            BackendChoice::Gavina => Arc::new(GavinaBackend {
                arch: self.arch.clone(),
                tables: self.tables.clone(),
                seed: self.seed,
            }),
            BackendChoice::Gls(ctx) => Arc::new(GlsBackend {
                arch: self.arch.clone(),
                ctx,
                seed: self.seed,
            }),
            BackendChoice::Custom(b) => b,
        };
        Ok(Engine {
            model,
            backend,
            arch: self.arch,
            tables: self.tables,
            seed: self.seed,
            threads: self.threads,
            policy: self.policy,
            ilp,
        })
    }
}

/// Structural weight-map validation: every tensor the forward pass will
/// touch must exist with the right kind and (where cheap to check) shape,
/// so a misconfigured engine fails at build time instead of panicking on
/// the first request.
fn validate_weights(weights: &TensorMap, width_mult: f64) -> Result<(), GavinaError> {
    let need = |name: &str| -> Result<&[usize], GavinaError> {
        weights
            .get(name)
            .and_then(AnyTensor::as_f32)
            .map(|(dims, _)| dims)
            .ok_or_else(|| GavinaError::Config(format!("weights: missing f32 tensor '{name}'")))
    };
    // Conv kernels must be 4-D HWIO with the channel chain the topology
    // implies — a mismatch must be a typed build error, not wrong logits
    // (lowering re-asserts this, but with a panic).
    let need_conv = |name: &str, cin: usize, cout: usize| -> Result<(), GavinaError> {
        let dims = need(name)?;
        if dims.len() != 4 || dims[2] != cin || dims[3] != cout {
            return Err(GavinaError::Config(format!(
                "{name} has shape {dims:?}, want [k,k,{cin},{cout}]"
            )));
        }
        check_reduction_dim(name, dims)?;
        Ok(())
    };
    // BN tensors must match the conv's output width — lowering folds
    // them per channel, so a length mismatch must be a typed build error,
    // not a panic inside `PlannedModel::lower`.
    let need_bn = |bn: &str, cout: usize| -> Result<(), GavinaError> {
        for part in ["scale", "bias", "mean", "var"] {
            let name = format!("{bn}/{part}");
            let dims = need(&name)?;
            if dims.iter().product::<usize>() != cout {
                return Err(GavinaError::Config(format!(
                    "{name} has shape {dims:?}, want [{cout}]"
                )));
            }
        }
        Ok(())
    };
    let d0 = need("conv0/w")?;
    let c0 = ch(64, width_mult);
    if d0.len() != 4 || d0[2] != 3 || d0[3] != c0 {
        return Err(GavinaError::Config(format!(
            "conv0/w has shape {d0:?}, want [k,k,3,{c0}] at width_mult {width_mult}"
        )));
    }
    check_reduction_dim("conv0/w", d0)?;
    need_bn("bn0", c0)?;
    let mut cin = c0;
    for (si, (c, stride)) in STAGES.iter().enumerate() {
        let cout = ch(*c, width_mult);
        for bi in 0..BLOCKS_PER_STAGE {
            let s = if bi == 0 { *stride } else { 1 };
            let p = format!("s{si}b{bi}");
            need_conv(&format!("{p}/conv1/w"), cin, cout)?;
            need_bn(&format!("{p}/bn1"), cout)?;
            need_conv(&format!("{p}/conv2/w"), cout, cout)?;
            need_bn(&format!("{p}/bn2"), cout)?;
            // The executor keys the shortcut conv off its presence; when
            // topology demands one, require it (and its BN). When it
            // demands an identity shortcut, a stray projection conv must
            // be rejected here — lowering would otherwise emit a plan
            // the fixed-length G vector has no slot for, and panic.
            if s != 1 || cin != cout {
                need_conv(&format!("{p}/down/w"), cin, cout)?;
                need_bn(&format!("{p}/dbn"), cout)?;
            } else if weights.contains_key(&format!("{p}/down/w")) {
                return Err(GavinaError::Config(format!(
                    "{p}/down/w present but block {p} has an identity shortcut \
                     (stride 1, {cin} channels in and out)"
                )));
            }
            cin = cout;
        }
    }
    let fd = need("fc/w")?;
    if fd.len() != 2 || fd[0] != cin {
        return Err(GavinaError::Config(format!(
            "fc/w has shape {fd:?}, want [{cin}, classes]"
        )));
    }
    let classes = fd[1];
    let fb = need("fc/b")?;
    if fb.iter().product::<usize>() != classes {
        return Err(GavinaError::Config(format!(
            "fc/b has shape {fb:?}, want [{classes}]"
        )));
    }
    Ok(())
}

/// The reduction axis `C = k·k·cin` a conv lowers to must fit the
/// bit-serial data path's `u16` iPE outputs
/// ([`crate::dnn::MAX_REDUCTION_DIM`]) — an oversized reduction would
/// silently truncate popcounts into wrong logits, so it must fail here at
/// `build()` with a typed error.
fn check_reduction_dim(name: &str, dims: &[usize]) -> Result<(), GavinaError> {
    let c_dim = dims[0] * dims[1] * dims[2];
    if c_dim > crate::dnn::MAX_REDUCTION_DIM {
        return Err(GavinaError::Config(format!(
            "{name}: reduction axis k·k·cin = {c_dim} exceeds the bit-serial \
             data path's maximum of {} (u16 iPE outputs would truncate)",
            crate::dnn::MAX_REDUCTION_DIM
        )));
    }
    Ok(())
}

/// The immutable inference engine: share it across threads behind an
/// `Arc`, call [`Engine::infer`] / [`Engine::infer_batched`], or start a
/// QoS serving [`Service`] with [`Engine::serve`].
pub struct Engine {
    /// The compiled data plane: weights quantized, bit-plane-packed and
    /// BN-folded exactly once, at [`EngineBuilder::build`]. Also the
    /// single source of truth for precision, width multiplier and the
    /// resolved per-layer G vector — the schedules the model actually
    /// runs can never drift from what the accessors report.
    model: Arc<PlannedModel>,
    backend: Arc<dyn ExecBackend>,
    arch: ArchConfig,
    tables: Option<Arc<ErrorTables>>,
    seed: u64,
    threads: usize,
    policy: GavPolicy,
    ilp: Option<IlpReport>,
}

impl std::fmt::Debug for Engine {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Engine")
            .field("backend", &self.backend.name())
            .field("precision", &self.model.prec())
            .field("policy", &self.policy)
            .field("width_mult", &self.model.width_mult())
            .field("seed", &self.seed)
            .field("threads", &self.threads)
            .field("layer_gs", &self.model.layer_gs())
            .finish_non_exhaustive()
    }
}

impl Engine {
    fn executor(&self) -> Executor<'_> {
        Executor::planned(&self.model, self.backend.as_ref())
    }

    fn check_images(&self, images: &[f32], n: usize) -> Result<(), GavinaError> {
        if n == 0 {
            return Err(GavinaError::Config("cannot infer on zero images".into()));
        }
        if images.len() != n * IMAGE_LEN {
            return Err(GavinaError::Shape {
                what: format!("image batch (n={n})"),
                expected: n * IMAGE_LEN,
                got: images.len(),
            });
        }
        Ok(())
    }

    /// Forward one batch of `n` NHWC images in `[0, 1]` (flat, `n · 3072`
    /// floats). Returns logits plus the accelerator counters.
    pub fn infer(&self, images: &[f32], n: usize) -> Result<ForwardResult, GavinaError> {
        self.check_images(images, n)?;
        Ok(self.executor().forward(images, n))
    }

    /// Forward a large set in internal mini-batches of `batch` images
    /// (bounds im2col memory), accumulating counters.
    pub fn infer_batched(
        &self,
        images: &[f32],
        n: usize,
        batch: usize,
    ) -> Result<ForwardResult, GavinaError> {
        self.check_images(images, n)?;
        if batch == 0 {
            return Err(GavinaError::Config("mini-batch size must be ≥ 1".into()));
        }
        Ok(self.executor().forward_batched(images, n, batch))
    }

    /// Deterministic seeded inference for one shard of a larger batch:
    /// `stream` is XOR-mixed into the backend's per-layer seed, so shards
    /// executed on different threads reproduce bit-exactly.
    pub fn infer_shard(
        &self,
        images: &[f32],
        n: usize,
        stream: u64,
    ) -> Result<ForwardResult, GavinaError> {
        self.check_images(images, n)?;
        let mut ex = self.executor();
        ex.stream = stream;
        // Single-executor path: spend the engine's thread budget inside
        // the fused activation prologue (bit-identical at any count — the
        // batch-parallel paths below keep their sub-executors serial
        // instead, so the two levels never multiply).
        ex.threads = self.threads;
        Ok(ex.forward(images, n))
    }

    /// Execute `n` independent images, splitting them into contiguous
    /// sub-batches across the engine's `threads` scoped workers (each a
    /// deterministic [`Engine::infer_shard`] stream), and merge the
    /// results in request order. `base_stream` namespaces the shard
    /// streams (the serving workers pass a per-worker value).
    pub fn infer_parallel(
        &self,
        images: &[f32],
        n: usize,
        base_stream: u64,
    ) -> Result<ForwardResult, GavinaError> {
        self.check_images(images, n)?;
        let threads = parallel::resolve_threads(self.threads);
        if threads <= 1 || n <= 1 {
            return self.infer_shard(images, n, base_stream);
        }
        // Contiguous sub-batches, one per thread, merged in request order.
        let chunk = n.div_ceil(threads.min(n));
        let starts: Vec<usize> = (0..n).step_by(chunk).collect();
        let parts = parallel::parallel_map(&starts, starts.len(), |ci, &i0| {
            let bn = chunk.min(n - i0);
            let mut ex = self.executor();
            ex.stream = base_stream ^ (ci as u64).wrapping_mul(0x9E37_79B9);
            ex.forward(&images[i0 * IMAGE_LEN..(i0 + bn) * IMAGE_LEN], bn)
        });
        let mut logits = Vec::with_capacity(n * 10);
        let mut stats = ForwardStats::default();
        let mut classes = 0;
        for part in parts {
            logits.extend_from_slice(&part.logits);
            classes = part.classes;
            stats.absorb(&part.stats);
        }
        Ok(ForwardResult {
            logits,
            n,
            classes,
            stats,
        })
    }

    fn check_rows(&self, rows: &[&[f32]]) -> Result<(), GavinaError> {
        if rows.is_empty() {
            return Err(GavinaError::Config("cannot infer on zero images".into()));
        }
        for (i, r) in rows.iter().enumerate() {
            if r.len() != IMAGE_LEN {
                return Err(GavinaError::Shape {
                    what: format!("packed row {i}"),
                    expected: IMAGE_LEN,
                    got: r.len(),
                });
            }
        }
        Ok(())
    }

    /// Forward a cross-request packed batch: the rows share one GEMM
    /// A-side per layer, but activations are quantized with **per-image**
    /// scales, so each row's logits are bit-identical to running that row
    /// alone through [`Engine::infer_shard`] with the same `stream`
    /// (columns of the lowered GEMM never mix images). This is what lets
    /// the serve plane's continuous batcher pack requests from different
    /// sessions — including exact-tier traffic — into one batch without
    /// coupling their numerics.
    pub fn infer_rows(&self, rows: &[&[f32]], stream: u64) -> Result<ForwardResult, GavinaError> {
        self.check_rows(rows)?;
        let mut ex = self.executor();
        ex.stream = stream;
        // As in `infer_shard`: the single-executor row path parallelizes
        // the prologue; the chunked path keeps sub-executors serial.
        ex.threads = self.threads;
        Ok(ex.forward_rows(rows))
    }

    /// [`Engine::infer_rows`] split into contiguous sub-batches across
    /// the engine's `threads` scoped workers, with the same per-chunk
    /// stream derivation as [`Engine::infer_parallel`], merged in request
    /// order.
    pub fn infer_rows_parallel(
        &self,
        rows: &[&[f32]],
        base_stream: u64,
    ) -> Result<ForwardResult, GavinaError> {
        self.check_rows(rows)?;
        let n = rows.len();
        let threads = parallel::resolve_threads(self.threads);
        if threads <= 1 || n <= 1 {
            return self.infer_rows(rows, base_stream);
        }
        let chunk = n.div_ceil(threads.min(n));
        let starts: Vec<usize> = (0..n).step_by(chunk).collect();
        let parts = parallel::parallel_map(&starts, starts.len(), |ci, &i0| {
            let bn = chunk.min(n - i0);
            let mut ex = self.executor();
            ex.stream = base_stream ^ (ci as u64).wrapping_mul(0x9E37_79B9);
            ex.forward_rows(&rows[i0..i0 + bn])
        });
        let mut logits = Vec::with_capacity(n * 10);
        let mut stats = ForwardStats::default();
        let mut classes = 0;
        for part in parts {
            logits.extend_from_slice(&part.logits);
            classes = part.classes;
            stats.absorb(&part.stats);
        }
        Ok(ForwardResult {
            logits,
            n,
            classes,
            stats,
        })
    }

    /// Start the QoS serving layer (bounded admission, tier engines,
    /// batcher + worker pool, optional governor) over this engine. Takes
    /// the `Arc` by value — `Arc::clone(&engine).serve(…)` keeps a local
    /// handle alive alongside the service. Fails with a typed error when
    /// the options are invalid or a tier policy cannot resolve.
    pub fn serve(self: Arc<Self>, opts: ServeOptions) -> Result<Service, GavinaError> {
        Service::start(self, opts)
    }

    /// The bit-exact reference replica for canary re-execution: this
    /// engine rescheduled under [`GavPolicy::Exact`] (fully guarded, no
    /// error injection), sharing its packed weight planes. Exact
    /// execution is stream-independent, so the reference reproduces
    /// [`Engine::infer`] for any served row regardless of the batch or
    /// injection stream it originally rode in.
    pub fn exact_reference(&self) -> Result<Engine, GavinaError> {
        self.with_policy(GavPolicy::Exact)
    }

    /// Re-execute already-served rows for the canary observability loop
    /// (see [`crate::canary`]). This entry point deliberately lives on
    /// the engine, *below* the serving stack: it never touches the
    /// session, the bounded-admission semaphore or the dispatch queues,
    /// so canary re-runs cannot consume client capacity by construction.
    /// Runs with `stream = 0`, the standalone-inference stream — on an
    /// exact/guarded engine the result is stream-independent and
    /// bit-identical to [`Engine::infer`] row for row.
    pub fn canary_rerun(&self, rows: &[&[f32]]) -> Result<ForwardResult, GavinaError> {
        self.infer_rows(rows, 0)
    }

    /// The uniform-G schedule that best represents this engine's resolved
    /// allocation ([`GavSchedule::representative`]) — what energy/TOP-per-W
    /// modelling of this engine's traffic should use.
    pub fn effective_schedule(&self) -> GavSchedule {
        GavSchedule::representative(self.precision(), &self.layer_gs())
    }

    /// Per-layer sensitivity profile (paper Fig. 8a) on the given images;
    /// needs calibrated error tables.
    pub fn profile_layers(
        &self,
        images: &[f32],
        n: usize,
        batch: usize,
    ) -> Result<Vec<LayerChoices>, GavinaError> {
        self.check_images(images, n)?;
        let tables = self.tables.as_ref().ok_or_else(|| {
            GavinaError::Config("layer profiling needs calibrated error tables".into())
        })?;
        policy::profile_layer_choices(
            &self.model,
            &self.arch,
            tables,
            self.seed,
            &ProfileSet {
                images: images.to_vec(),
                n,
                batch: batch.max(1),
            },
        )
    }

    /// Profile + solve the §IV-D ILP for a target average G.
    pub fn allocate(
        &self,
        gtar: f64,
        images: &[f32],
        n: usize,
        batch: usize,
    ) -> Result<Allocation, GavinaError> {
        let choices = self.profile_layers(images, n, batch)?;
        Ok(GavAllocator::new(choices).solve(gtar))
    }

    /// A new engine sharing this one's weights/tables/backend config but
    /// with a different G policy. [`GavPolicy::IlpBudget`] is rejected
    /// here (it needs a profile set — use [`EngineBuilder`]).
    pub fn with_policy(&self, policy: GavPolicy) -> Result<Engine, GavinaError> {
        if matches!(policy, GavPolicy::IlpBudget { .. }) {
            return Err(GavinaError::Config(
                "with_policy cannot resolve IlpBudget; use EngineBuilder::profile_set".into(),
            ));
        }
        let (layer_gs, _) = policy::resolve(
            &policy,
            &self.model,
            &self.arch,
            self.tables.as_ref(),
            self.seed,
            None,
        )?;
        Ok(Engine {
            // Re-resolve the schedules only — the packed weight planes
            // and folded BN constants are shared with this engine.
            model: Arc::new(self.model.with_layer_gs(&layer_gs)),
            backend: Arc::clone(&self.backend),
            arch: self.arch.clone(),
            tables: self.tables.clone(),
            seed: self.seed,
            threads: self.threads,
            policy,
            ilp: None,
        })
    }

    // --- accessors ------------------------------------------------------

    pub fn precision(&self) -> Precision {
        self.model.prec()
    }

    pub fn arch(&self) -> &ArchConfig {
        &self.arch
    }

    pub fn width_mult(&self) -> f64 {
        self.model.width_mult()
    }

    pub fn seed(&self) -> u64 {
        self.seed
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    /// The resolved per-layer G vector (index = conv layer in execution
    /// order, see [`crate::dnn::conv_layer_names`]), read back from the
    /// compiled schedules.
    pub fn layer_gs(&self) -> Vec<u32> {
        self.model.layer_gs()
    }

    pub fn policy(&self) -> &GavPolicy {
        &self.policy
    }

    /// ILP profiling artifacts when the engine was built with
    /// [`GavPolicy::IlpBudget`].
    pub fn ilp_report(&self) -> Option<&IlpReport> {
        self.ilp.as_ref()
    }

    pub fn backend_name(&self) -> &'static str {
        self.backend.name()
    }

    /// The compiled data plane (per-layer plans, packed weight planes).
    pub fn model(&self) -> &PlannedModel {
        &self.model
    }

    pub fn tables(&self) -> Option<&Arc<ErrorTables>> {
        self.tables.as_ref()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Prng;

    fn rand_images(rng: &mut Prng, n: usize) -> Vec<f32> {
        (0..n * IMAGE_LEN).map(|_| rng.next_f32()).collect()
    }

    fn tiny_builder() -> EngineBuilder {
        EngineBuilder::new()
            .synthetic_weights(0.125, 1)
            .precision(Precision::new(2, 2))
            .arch(ArchConfig::tiny())
            .seed(3)
    }

    #[test]
    fn build_validates_weights_and_policy() {
        assert!(matches!(
            EngineBuilder::new().build(),
            Err(GavinaError::Config(_))
        ));
        // width_mult mismatch: synthetic 0.125 weights claimed as 0.25.
        let err = EngineBuilder::new()
            .weights(synth::synthetic_weights(0.125, 1))
            .width_mult(0.25)
            .build()
            .unwrap_err();
        assert!(err.to_string().contains("conv0/w"), "{err}");
        // Uniform G beyond G_max.
        assert!(tiny_builder()
            .policy(GavPolicy::Uniform(99))
            .build()
            .is_err());
        // IlpBudget on the float reference makes no sense.
        assert!(tiny_builder()
            .backend_float()
            .policy(GavPolicy::IlpBudget { gtar: 1.0 })
            .build()
            .is_err());
    }

    #[test]
    fn oversized_reduction_axis_is_a_typed_build_error() {
        // A 3×3 conv over ≤ 7281 input channels fits the u16 iPE
        // outputs; beyond that, build() must fail typed, not truncate.
        assert!(check_reduction_dim("x/w", &[3, 3, 512, 64]).is_ok());
        assert!(check_reduction_dim("x/w", &[3, 3, 7281, 64]).is_ok());
        let err = check_reduction_dim("x/w", &[3, 3, 8000, 64]).unwrap_err();
        assert!(matches!(err, GavinaError::Config(_)));
        assert!(err.to_string().contains("reduction axis"), "{err}");
    }

    #[test]
    fn infer_checks_shapes_instead_of_panicking() {
        let engine = tiny_builder().build().unwrap();
        assert!(matches!(
            engine.infer(&[0.0; 7], 1),
            Err(GavinaError::Shape { .. })
        ));
        assert!(engine.infer(&[], 0).is_err());
        let mut rng = Prng::new(5);
        let imgs = rand_images(&mut rng, 1);
        assert_eq!(engine.infer(&imgs, 1).unwrap().logits.len(), 10);
    }

    #[test]
    fn float_and_guarded_engine_agree() {
        let mut rng = Prng::new(7);
        let imgs = rand_images(&mut rng, 2);
        let exact = tiny_builder().backend_float().build().unwrap();
        let guarded = tiny_builder().build().unwrap();
        let a = exact.infer(&imgs, 2).unwrap();
        let b = guarded.infer(&imgs, 2).unwrap();
        for (x, y) in a.logits.iter().zip(&b.logits) {
            assert!((x - y).abs() < 1e-3, "{x} vs {y}");
        }
        assert_eq!(a.stats.cycles, 0);
        assert!(b.stats.cycles > 0);
        assert_eq!(exact.backend_name(), "float");
        assert_eq!(guarded.backend_name(), "gavina-sim");
    }

    #[test]
    fn with_policy_rebinds_layer_gs() {
        let engine = tiny_builder().build().unwrap();
        let max_g = engine.precision().max_g();
        assert_eq!(engine.layer_gs(), vec![max_g; 20]);
        let uv = engine.with_policy(GavPolicy::Uniform(0)).unwrap();
        assert_eq!(uv.layer_gs(), vec![0; 20]);
        assert!(engine
            .with_policy(GavPolicy::IlpBudget { gtar: 1.0 })
            .is_err());
    }

    #[test]
    fn apply_config_loads_engine_section_and_rejects_typos() {
        let cfg = crate::config::parse(
            "[engine]\nprecision = \"a2w2\"\npolicy = \"uniform\"\ng = 1\nseed = 5\nthreads = 2\n",
        )
        .unwrap();
        let engine = EngineBuilder::new()
            .synthetic_weights(0.125, 1)
            .arch(ArchConfig::tiny())
            .apply_config(&cfg)
            .unwrap()
            .build()
            .unwrap();
        assert_eq!(engine.precision(), Precision::new(2, 2));
        assert_eq!(engine.seed(), 5);
        assert_eq!(engine.threads(), 2);
        assert_eq!(engine.layer_gs(), vec![1; 20]);

        // Legacy configs keep g under [run]; policy = "uniform" must
        // still resolve (engine.* would win if both were present).
        let cfg = crate::config::parse("[run]\ng = 2\n[engine]\npolicy = \"uniform\"\n").unwrap();
        let engine = EngineBuilder::new()
            .synthetic_weights(0.125, 1)
            .precision(Precision::new(2, 2))
            .arch(ArchConfig::tiny())
            .apply_config(&cfg)
            .unwrap()
            .build()
            .unwrap();
        assert_eq!(engine.layer_gs(), vec![2; 20]);

        // Bare-key inference must not override an explicitly chosen
        // policy (library callers applying a legacy config).
        let cfg = crate::config::parse("[run]\ng = 1\n").unwrap();
        let engine = tiny_builder()
            .policy(GavPolicy::Exact)
            .apply_config(&cfg)
            .unwrap()
            .build()
            .unwrap();
        assert_eq!(engine.layer_gs(), vec![Precision::new(2, 2).max_g(); 20]);

        // Typos are hard errors, not silent defaults.
        let cfg = crate::config::parse("[engine]\nthread = 2\n").unwrap();
        let err = match EngineBuilder::new().apply_config(&cfg) {
            Err(e) => e,
            Ok(_) => panic!("typoed [engine] key must be rejected"),
        };
        assert!(err.to_string().contains("unknown [engine] key 'thread'"), "{err}");
        // So are invalid values (negative seed must not wrap).
        let cfg = crate::config::parse("[engine]\nseed = -1\n").unwrap();
        assert!(EngineBuilder::new().apply_config(&cfg).is_err());
        let cfg = crate::config::parse("[engine]\npolicy = \"bogus\"\n").unwrap();
        assert!(EngineBuilder::new().apply_config(&cfg).is_err());
    }

    #[test]
    fn infer_parallel_matches_shard_partition() {
        // The threaded path must produce exactly the logits of serially
        // running each sub-batch with the same per-chunk streams.
        let engine = tiny_builder().threads(2).build().unwrap();
        let n = 5; // odd: chunks of 3 + 2
        let mut rng = Prng::new(10);
        let images = rand_images(&mut rng, n);
        let par = engine.infer_parallel(&images, n, 0).unwrap();
        assert_eq!(par.logits.len(), n * par.classes);

        let chunk = n.div_ceil(2);
        let mut expect = Vec::new();
        for (ci, i0) in (0..n).step_by(chunk).enumerate() {
            let bn = chunk.min(n - i0);
            let out = engine
                .infer_shard(
                    &images[i0 * IMAGE_LEN..(i0 + bn) * IMAGE_LEN],
                    bn,
                    (ci as u64).wrapping_mul(0x9E37_79B9),
                )
                .unwrap();
            expect.extend_from_slice(&out.logits);
        }
        assert_eq!(par.logits, expect);

        // And a second identical call is bit-identical (deterministic).
        let again = engine.infer_parallel(&images, n, 0).unwrap();
        assert_eq!(par.logits, again.logits);
        assert_eq!(par.stats.cycles, again.stats.cycles);
    }

    #[test]
    fn infer_rows_packed_batch_equals_per_request_under_exact() {
        // Continuous-batching contract: a cross-request packed batch
        // under a deterministic policy equals per-request inference row
        // for row — per-image activation scales make batching
        // bit-transparent.
        let engine = tiny_builder().policy(GavPolicy::Exact).build().unwrap();
        let mut rng = Prng::new(40);
        let rows: Vec<Vec<f32>> = (0..3).map(|_| rand_images(&mut rng, 1)).collect();
        let refs: Vec<&[f32]> = rows.iter().map(|r| r.as_slice()).collect();
        let packed = engine.infer_rows(&refs, 7).unwrap();
        let classes = packed.classes;
        for (i, row) in rows.iter().enumerate() {
            let alone = engine.infer(row, 1).unwrap();
            assert_eq!(
                packed.logits[i * classes..(i + 1) * classes],
                alone.logits[..],
                "packed row {i} must equal standalone infer"
            );
        }
        // Bad row shapes are typed errors, not panics.
        let bad: Vec<&[f32]> = vec![&rows[0][..100]];
        assert!(matches!(
            engine.infer_rows(&bad, 0),
            Err(GavinaError::Shape { .. })
        ));
        let none: Vec<&[f32]> = Vec::new();
        assert!(engine.infer_rows(&none, 0).is_err());
    }

    #[test]
    fn infer_rows_parallel_matches_serial_rows_partition() {
        // The threaded rows path must reproduce the serial per-chunk
        // streams exactly, like infer_parallel does for flat batches.
        let engine = tiny_builder().threads(2).build().unwrap();
        let n = 5;
        let mut rng = Prng::new(41);
        let rows: Vec<Vec<f32>> = (0..n).map(|_| rand_images(&mut rng, 1)).collect();
        let refs: Vec<&[f32]> = rows.iter().map(|r| r.as_slice()).collect();
        let par = engine.infer_rows_parallel(&refs, 5).unwrap();

        let chunk = n.div_ceil(2);
        let mut expect = Vec::new();
        for (ci, i0) in (0..n).step_by(chunk).enumerate() {
            let bn = chunk.min(n - i0);
            let out = engine
                .infer_rows(&refs[i0..i0 + bn], 5 ^ (ci as u64).wrapping_mul(0x9E37_79B9))
                .unwrap();
            expect.extend_from_slice(&out.logits);
        }
        assert_eq!(par.logits, expect);
    }
}
