//! Per-layer GAV allocation by exact branch-and-bound ILP (paper §IV-D).
//!
//! The paper: *"we develop an optimization algorithm that finds the
//! optimal per-layer allocation of G based on an integer linear
//! programming (ILP) approach … we choose to minimize the perturbation of
//! the network outputs … We constrain the problem by setting a target
//! average G_tar such that weigh_avg([G_0, …, G_{L−1}]) < G_tar"*.
//!
//! Formally a **multiple-choice knapsack**: per layer `l` choose one
//! option `g ∈ 0..=G_max` with cost `mse[l][g]` (output perturbation when
//! only layer `l` runs at G = g) and weight `w_l · g` (`w_l` = the layer's
//! operation count); minimize total cost subject to
//! `Σ w_l·g_l ≤ G_tar · Σ w_l`.
//!
//! Solved exactly with depth-first branch-and-bound using the classic
//! LP-relaxation bound: per layer, keep the lower convex hull of
//! (weight, cost) options; the greedy fractional completion over hull
//! segments lower-bounds any integer completion. The instance is small
//! (≤ ~21 layers × ≤ 17 options), so exact search is instant — no
//! commercial solver needed (DESIGN.md §Substitutions).

/// One layer's menu of options.
#[derive(Clone, Debug)]
pub struct LayerChoices {
    /// Weight units per unit of G (the layer's op count).
    pub ops: f64,
    /// `cost[g]` = perturbation when this layer runs at G = g.
    pub cost: Vec<f64>,
}

/// Allocation result.
#[derive(Clone, Debug, PartialEq)]
pub struct Allocation {
    /// Chosen G per layer.
    pub gs: Vec<u32>,
    /// Total cost Σ mse.
    pub cost: f64,
    /// Achieved op-weighted average G.
    pub avg_g: f64,
}

/// Per-layer lower convex hull of (g, cost): candidate option indices in
/// increasing g with strictly decreasing cost and decreasing
/// |Δcost|/Δg slopes.
fn convex_hull(cost: &[f64]) -> Vec<usize> {
    // Start from g=0 and keep points that improve cost; then enforce
    // convexity (slopes of cost decrease must be non-increasing in
    // magnitude as g grows).
    let mut pts: Vec<usize> = Vec::new();
    let mut best = f64::INFINITY;
    for (g, &c) in cost.iter().enumerate() {
        if c < best - 1e-18 || pts.is_empty() {
            pts.push(g);
            best = c;
        }
    }
    // Convexify.
    let mut hull: Vec<usize> = Vec::new();
    for &g in &pts {
        while hull.len() >= 2 {
            let a = hull[hull.len() - 2];
            let b = hull[hull.len() - 1];
            let s1 = (cost[b] - cost[a]) / (b - a) as f64;
            let s2 = (cost[g] - cost[b]) / (g - b) as f64;
            if s1 >= s2 {
                // b is above the segment a—g: drop it.
                hull.pop();
            } else {
                break;
            }
        }
        hull.push(g);
    }
    hull
}

/// Exact branch-and-bound solver.
pub struct GavAllocator {
    layers: Vec<LayerChoices>,
    hulls: Vec<Vec<usize>>,
}

impl GavAllocator {
    pub fn new(layers: Vec<LayerChoices>) -> Self {
        assert!(!layers.is_empty());
        let hulls = layers.iter().map(|l| convex_hull(&l.cost)).collect();
        Self { layers, hulls }
    }

    /// LP lower bound for layers `from..` with remaining weight budget:
    /// start every remaining layer at its cheapest-weight hull point
    /// (g = hull[0]) and greedily buy the best Δcost/Δweight hull segments
    /// until the budget runs out (fractional last purchase).
    fn lp_bound(&self, from: usize, budget: f64) -> f64 {
        let mut base_cost = 0.0;
        let mut base_weight = 0.0;
        // Candidate segments: (Δcost (<0), Δweight, ratio).
        let mut segs: Vec<(f64, f64)> = Vec::new(); // (gain per weight, weight)
        for l in from..self.layers.len() {
            let hull = &self.hulls[l];
            let ops = self.layers[l].ops;
            base_cost += self.layers[l].cost[hull[0]];
            base_weight += ops * hull[0] as f64;
            for w in hull.windows(2) {
                let (a, b) = (w[0], w[1]);
                let dcost = self.layers[l].cost[a] - self.layers[l].cost[b]; // ≥ 0
                let dweight = ops * (b - a) as f64;
                if dweight > 0.0 && dcost > 0.0 {
                    segs.push((dcost / dweight, dweight));
                }
            }
        }
        let mut remaining = budget - base_weight;
        if remaining < -1e-9 {
            return f64::INFINITY; // even the cheapest completion infeasible
        }
        // Convexity makes per-layer segments already sorted by decreasing
        // gain; globally we must sort.
        segs.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());
        let mut cost = base_cost;
        for (gain, w) in segs {
            if remaining <= 0.0 {
                break;
            }
            let take = w.min(remaining);
            cost -= gain * take;
            remaining -= take;
        }
        cost
    }

    /// Solve: minimize Σ cost s.t. op-weighted average G ≤ `g_target`.
    pub fn solve(&self, g_target: f64) -> Allocation {
        let n = self.layers.len();
        let total_ops: f64 = self.layers.iter().map(|l| l.ops).sum();
        let budget = g_target * total_ops;

        let mut best_cost = f64::INFINITY;
        let mut best: Vec<u32> = vec![0; n];
        let mut cur: Vec<u32> = vec![0; n];

        // DFS with the LP bound. Options per layer restricted to the hull
        // is NOT valid for exactness (an interior point could be optimal
        // when budgets are tight), so branch over all options but bound
        // with the hull LP.
        fn dfs(
            s: &GavAllocator,
            l: usize,
            used: f64,
            cost: f64,
            budget: f64,
            cur: &mut Vec<u32>,
            best_cost: &mut f64,
            best: &mut Vec<u32>,
        ) {
            if cost >= *best_cost {
                return;
            }
            if l == s.layers.len() {
                *best_cost = cost;
                best.copy_from_slice(cur);
                return;
            }
            if cost + s.lp_bound(l, budget - used) >= *best_cost {
                return;
            }
            // Try options cheapest-cost-first (larger g first since cost
            // is ~decreasing) to find good incumbents early.
            let layer = &s.layers[l];
            let mut order: Vec<usize> = (0..layer.cost.len()).collect();
            order.sort_by(|&a, &b| layer.cost[a].partial_cmp(&layer.cost[b]).unwrap());
            for g in order {
                let w = layer.ops * g as f64;
                if used + w > budget + 1e-9 {
                    continue;
                }
                cur[l] = g as u32;
                dfs(s, l + 1, used + w, cost + layer.cost[g], budget, cur, best_cost, best);
            }
        }

        dfs(self, 0, 0.0, 0.0, budget, &mut cur, &mut best_cost, &mut best);
        assert!(
            best_cost.is_finite(),
            "no feasible allocation (g=0 must always be feasible)"
        );
        let used: f64 = best
            .iter()
            .enumerate()
            .map(|(l, &g)| self.layers[l].ops * g as f64)
            .sum();
        Allocation {
            gs: best,
            cost: best_cost,
            avg_g: used / total_ops,
        }
    }
}

/// Brute-force reference (tests only; exponential).
pub fn solve_brute(layers: &[LayerChoices], g_target: f64) -> Allocation {
    let total_ops: f64 = layers.iter().map(|l| l.ops).sum();
    let budget = g_target * total_ops;
    let mut best_cost = f64::INFINITY;
    let mut best = vec![0u32; layers.len()];
    let mut cur = vec![0u32; layers.len()];
    fn rec(
        layers: &[LayerChoices],
        l: usize,
        used: f64,
        cost: f64,
        budget: f64,
        cur: &mut Vec<u32>,
        best_cost: &mut f64,
        best: &mut Vec<u32>,
    ) {
        if l == layers.len() {
            if cost < *best_cost {
                *best_cost = cost;
                best.copy_from_slice(cur);
            }
            return;
        }
        for g in 0..layers[l].cost.len() {
            let w = layers[l].ops * g as f64;
            if used + w > budget + 1e-9 {
                continue;
            }
            cur[l] = g as u32;
            rec(layers, l + 1, used + w, cost + layers[l].cost[g], budget, cur, best_cost, best);
        }
    }
    rec(layers, 0, 0.0, 0.0, budget, &mut cur, &mut best_cost, &mut best);
    let used: f64 = best
        .iter()
        .enumerate()
        .map(|(l, &g)| layers[l].ops * g as f64)
        .sum();
    Allocation {
        gs: best,
        cost: best_cost,
        avg_g: used / total_ops,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;

    fn rand_instance(rng: &mut crate::util::Prng, n_layers: usize, n_g: usize) -> Vec<LayerChoices> {
        let mut layers = Vec::with_capacity(n_layers);
        for _ in 0..n_layers {
            // Decreasing, roughly exponential cost in g (like Fig. 8a).
            let scale = rng.next_f64() * 10.0 + 0.1;
            let rate = rng.next_f64() * 1.5 + 0.3;
            let noise = 0.05;
            let mut cost = Vec::with_capacity(n_g);
            for g in 0..n_g {
                cost.push(
                    scale * (-(g as f64) * rate).exp() * (1.0 + noise * (rng.next_f64() - 0.5)),
                );
            }
            layers.push(LayerChoices {
                ops: rng.next_f64() * 100.0 + 1.0,
                cost,
            });
        }
        layers
    }

    #[test]
    fn matches_brute_force() {
        check("B&B == brute force", 40, |rng| {
            let n_layers = rng.int_in(1, 6) as usize;
            let n_g = rng.int_in(2, 6) as usize;
            let layers = rand_instance(rng, n_layers, n_g);
            let g_target = rng.next_f64() * (n_g - 1) as f64;
            let bb = GavAllocator::new(layers.clone()).solve(g_target);
            let bf = solve_brute(&layers, g_target);
            assert!(
                (bb.cost - bf.cost).abs() < 1e-9,
                "B&B {:.6} vs brute {:.6} (target {g_target})",
                bb.cost,
                bf.cost
            );
        });
    }

    #[test]
    fn budget_is_respected() {
        check("avg G within target", 30, |rng| {
            let n_layers = rng.int_in(2, 10) as usize;
            let layers = rand_instance(rng, n_layers, 9);
            let g_target = rng.next_f64() * 8.0;
            let a = GavAllocator::new(layers).solve(g_target);
            assert!(a.avg_g <= g_target + 1e-9, "avg {} > target {g_target}", a.avg_g);
        });
    }

    #[test]
    fn zero_budget_forces_all_zero() {
        let layers = vec![
            LayerChoices {
                ops: 5.0,
                cost: vec![3.0, 1.0, 0.1],
            },
            LayerChoices {
                ops: 1.0,
                cost: vec![2.0, 0.5, 0.0],
            },
        ];
        let a = GavAllocator::new(layers).solve(0.0);
        assert_eq!(a.gs, vec![0, 0]);
        assert!((a.cost - 5.0).abs() < 1e-12);
    }

    #[test]
    fn big_budget_takes_best_everywhere() {
        let layers = vec![
            LayerChoices {
                ops: 5.0,
                cost: vec![3.0, 1.0, 0.1],
            },
            LayerChoices {
                ops: 1.0,
                cost: vec![2.0, 0.5, 0.0],
            },
        ];
        let a = GavAllocator::new(layers).solve(2.0);
        assert_eq!(a.gs, vec![2, 2]);
        assert!((a.cost - 0.1).abs() < 1e-12);
    }

    #[test]
    fn sensitive_layers_get_more_guarding() {
        // Layer 0 is hugely sensitive (cost drops steeply with G), layer 1
        // barely cares: at a tight average budget the allocator must give
        // layer 0 the larger G (the Fig. 8a insight: the input layer gets
        // guarded first).
        let layers = vec![
            LayerChoices {
                ops: 10.0,
                cost: vec![100.0, 10.0, 0.1, 0.0],
            },
            LayerChoices {
                ops: 10.0,
                cost: vec![0.2, 0.19, 0.18, 0.17],
            },
        ];
        let a = GavAllocator::new(layers).solve(1.0);
        assert!(
            a.gs[0] > a.gs[1],
            "sensitive layer must get more guarding: {:?}",
            a.gs
        );
    }

    #[test]
    fn paper_scale_instance_is_fast_and_exact_vs_dp_spotcheck() {
        // 20 layers × 17 options — solve a sweep of targets; must finish
        // quickly and produce monotone cost in the target.
        let mut rng = crate::util::Prng::new(42);
        let layers = rand_instance(&mut rng, 20, 17);
        let solver = GavAllocator::new(layers);
        let mut last_cost = f64::INFINITY;
        for i in 0..8 {
            let t = 2.0 * i as f64;
            let a = solver.solve(t);
            assert!(a.cost <= last_cost + 1e-9, "cost must fall as budget grows");
            last_cost = a.cost;
        }
    }
}
