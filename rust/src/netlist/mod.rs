//! Gate-level elaboration of one GAVINA inner-product element (iPE).
//!
//! The paper evaluates undervolting errors with gate-level simulations of
//! the post-layout 12 nm netlist. We cannot ship that netlist, so this
//! module *builds* the equivalent circuit structure from scratch (see
//! DESIGN.md §Substitutions):
//!
//! ```text
//!   p[c]   = a[c] AND w[c]                 (C AND gates)
//!   sum    = Σ_c p[c]                      (3:2 carry-save compressor
//!                                           tree + final ripple-carry
//!                                           adder — the standard
//!                                           population-count datapath)
//! ```
//!
//! The CSA-tree + CPA structure is what gives the error model the paper's
//! physics: the compressor levels have near-uniform depth across bits,
//! while the final carry-propagate adder adds one ripple stage per bit of
//! significance — so the *MSB-side carry chains* are the deepest paths
//! and break first under undervolting, and they only switch when the sum
//! crosses a power-of-two boundary. Both §IV-C observations ("bit
//! dependency", "some locations near power-of-two values have larger
//! error rates") fall out of the structure.
//!
//! Gates are 1- or 2-input primitives (`AND/OR/XOR/NOT`) created in
//! topological order, so zero-delay functional evaluation is a single
//! forward pass and the event-driven simulator in [`crate::gls`] can attach
//! per-gate delays without re-sorting.

use crate::util::Prng;

/// Net identifier (index into the simulator's value array).
pub type NetId = u32;

/// Gate primitive kind.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum GateKind {
    And2,
    Or2,
    Xor2,
    Not,
}

impl GateKind {
    /// Relative intrinsic delay of the gate (unitless; scaled globally by
    /// the GLS calibration). XOR cells are ~1.6x slower than NAND-class
    /// cells in standard libraries; inverters faster.
    pub fn base_delay(self) -> f64 {
        match self {
            GateKind::And2 | GateKind::Or2 => 1.0,
            GateKind::Xor2 => 1.6,
            GateKind::Not => 0.6,
        }
    }

    /// Relative switched capacitance (drives the GLS dynamic-energy
    /// accounting; XOR cells are heavier).
    pub fn cap(self) -> f64 {
        match self {
            GateKind::And2 | GateKind::Or2 => 1.0,
            GateKind::Xor2 => 1.5,
            GateKind::Not => 0.5,
        }
    }

    pub fn n_inputs(self) -> usize {
        match self {
            GateKind::Not => 1,
            _ => 2,
        }
    }

    /// Evaluate the gate function.
    #[inline]
    pub fn eval(self, a: bool, b: bool) -> bool {
        match self {
            GateKind::And2 => a && b,
            GateKind::Or2 => a || b,
            GateKind::Xor2 => a ^ b,
            GateKind::Not => !a,
        }
    }
}

/// One gate instance. `inputs[1]` is ignored for 1-input kinds.
#[derive(Clone, Copy, Debug)]
pub struct Gate {
    pub kind: GateKind,
    pub inputs: [NetId; 2],
    pub out: NetId,
}

/// A combinational netlist with designated input and output nets.
#[derive(Clone, Debug)]
pub struct Netlist {
    /// Gates in topological order (inputs of gate i are either primary
    /// inputs or outputs of gates < i).
    pub gates: Vec<Gate>,
    /// Total nets: primary inputs first, then one per gate output.
    pub n_nets: usize,
    /// Activation input nets `a[0..C]`.
    pub a_inputs: Vec<NetId>,
    /// Weight input nets `w[0..C]`.
    pub w_inputs: Vec<NetId>,
    /// Sum output nets, LSB first (`ceil(log2(C+1))` of them).
    pub outputs: Vec<NetId>,
    /// Reduction width C.
    pub c_dim: usize,
}

/// Builder state for [`build_ipe`].
struct Builder {
    gates: Vec<Gate>,
    n_nets: usize,
    /// A constant-0 net (never driven; simulators initialise nets low).
    zero: NetId,
}

impl Builder {
    fn gate(&mut self, kind: GateKind, a: NetId, b: NetId) -> NetId {
        let out = self.n_nets as NetId;
        self.n_nets += 1;
        self.gates.push(Gate {
            kind,
            inputs: [a, b],
            out,
        });
        out
    }

    /// One 3:2 carry-save compressor level over three bit vectors: per
    /// bit position a full adder produces a sum bit (same weight) and a
    /// carry bit (next weight) — no ripple, constant depth. Returns
    /// `(sum_vec, carry_vec)` whose values add to `u + v + w`.
    ///
    /// Input vectors are dense little-endian (all positions `< len`
    /// populated), so positions with 2–3 bits form a prefix: the carry
    /// vector is dense after a constant-zero bit 0.
    fn csa(&mut self, u: &[NetId], v: &[NetId], w: &[NetId]) -> (Vec<NetId>, Vec<NetId>) {
        let width = u.len().max(v.len()).max(w.len());
        let mut s_out: Vec<NetId> = Vec::with_capacity(width);
        let mut c_out: Vec<NetId> = vec![self.zero]; // carry weight starts at bit 1
        for i in 0..width {
            let bits: Vec<NetId> = [u.get(i), v.get(i), w.get(i)]
                .into_iter()
                .flatten()
                .copied()
                .collect();
            match bits.as_slice() {
                [a, b, c] => {
                    let t = self.gate(GateKind::Xor2, *a, *b);
                    let s = self.gate(GateKind::Xor2, t, *c);
                    let g1 = self.gate(GateKind::And2, *a, *b);
                    let g2 = self.gate(GateKind::And2, t, *c);
                    let co = self.gate(GateKind::Or2, g1, g2);
                    s_out.push(s);
                    c_out.push(co);
                }
                [a, b] => {
                    let s = self.gate(GateKind::Xor2, *a, *b);
                    let co = self.gate(GateKind::And2, *a, *b);
                    s_out.push(s);
                    c_out.push(co);
                }
                [a] => s_out.push(*a),
                _ => {}
            }
        }
        // Trim a useless all-zero carry vector (possible for tiny widths).
        while c_out.len() > 1 && *c_out.last().unwrap() == self.zero {
            c_out.pop();
        }
        (s_out, c_out)
    }

    /// Ripple-carry add two little-endian bit vectors whose *values* are
    /// bounded by `max_u` and `max_v`; output has exactly
    /// `bits_for(max_u + max_v)` bits (the top carry is dropped when the
    /// value bound proves it zero).
    fn add_vectors(&mut self, u: &[NetId], v: &[NetId], max_u: u64, max_v: u64) -> Vec<NetId> {
        let out_w = crate::util::bits_for(max_u + max_v) as usize;
        let mut out = Vec::with_capacity(out_w);
        let mut carry: Option<NetId> = None;
        for i in 0..out_w {
            let a = u.get(i).copied();
            let b = v.get(i).copied();
            let (s, c) = match (a, b, carry) {
                (Some(a), Some(b), Some(cin)) => {
                    // Full adder: t = a^b; s = t^cin; cout = (a&b)|(t&cin)
                    let t = self.gate(GateKind::Xor2, a, b);
                    let s = self.gate(GateKind::Xor2, t, cin);
                    let g1 = self.gate(GateKind::And2, a, b);
                    let g2 = self.gate(GateKind::And2, t, cin);
                    let c = self.gate(GateKind::Or2, g1, g2);
                    (s, Some(c))
                }
                (Some(a), Some(b), None) => {
                    // Half adder.
                    let s = self.gate(GateKind::Xor2, a, b);
                    let c = self.gate(GateKind::And2, a, b);
                    (s, Some(c))
                }
                (Some(a), None, Some(cin)) | (None, Some(a), Some(cin)) => {
                    // Half adder with carry-in only.
                    let s = self.gate(GateKind::Xor2, a, cin);
                    let c = self.gate(GateKind::And2, a, cin);
                    (s, Some(c))
                }
                (Some(a), None, None) | (None, Some(a), None) => (a, None),
                (None, None, Some(cin)) => (cin, None),
                (None, None, None) => break,
            };
            out.push(s);
            carry = if i + 1 < out_w { c } else { None };
        }
        out
    }
}

/// Elaborate one iPE: `C` AND gates feeding a balanced ripple-carry adder
/// tree, outputs `ceil(log2(C+1))` sum bits.
pub fn build_ipe(c_dim: usize) -> Netlist {
    assert!(c_dim >= 1);
    let mut b = Builder {
        gates: Vec::new(),
        n_nets: 2 * c_dim + 1, // a[0..C], w[0..C], constant-0
        zero: (2 * c_dim) as NetId,
    };
    let a_inputs: Vec<NetId> = (0..c_dim as NetId).collect();
    let w_inputs: Vec<NetId> = (c_dim as NetId..2 * c_dim as NetId).collect();

    // AND array: C one-bit operands.
    let mut operands: Vec<Vec<NetId>> = (0..c_dim)
        .map(|c| vec![b.gate(GateKind::And2, a_inputs[c], w_inputs[c])])
        .collect();

    // 3:2 carry-save compressor tree: each level turns 3 operands into 2
    // with constant (carry-save) depth, until two remain.
    while operands.len() > 2 {
        let mut next = Vec::with_capacity(2 * operands.len() / 3 + 2);
        let mut it = operands.chunks_exact(3);
        for trio in it.by_ref() {
            let (s, c) = b.csa(&trio[0], &trio[1], &trio[2]);
            next.push(s);
            next.push(c);
        }
        next.extend(it.remainder().iter().cloned());
        operands = next;
    }

    // Final carry-propagate (ripple) adder: the only long carry chain —
    // one ripple stage per bit of significance, which is where the
    // MSB-deepest paths come from. The combined value is exactly the
    // popcount ≤ C, so the output width is bits_for(C) and the top carry
    // is structurally zero.
    let outputs = if operands.len() == 1 {
        operands.pop().unwrap()
    } else {
        let v = operands.pop().unwrap();
        let u = operands.pop().unwrap();
        b.add_vectors(&u, &v, c_dim as u64, 0)
    };
    debug_assert_eq!(outputs.len(), crate::util::bits_for(c_dim as u64) as usize);
    Netlist {
        gates: b.gates,
        n_nets: b.n_nets,
        a_inputs,
        w_inputs,
        outputs,
        c_dim,
    }
}

impl Netlist {
    /// Zero-delay functional evaluation: returns the sum for the given
    /// input bits (ground truth for the timing simulator and tests).
    pub fn eval(&self, a_bits: &[bool], w_bits: &[bool]) -> u64 {
        assert_eq!(a_bits.len(), self.c_dim);
        assert_eq!(w_bits.len(), self.c_dim);
        let mut values = vec![false; self.n_nets];
        values[..self.c_dim].copy_from_slice(a_bits);
        values[self.c_dim..2 * self.c_dim].copy_from_slice(w_bits);
        for g in &self.gates {
            let a = values[g.inputs[0] as usize];
            let b = if g.kind.n_inputs() == 2 {
                values[g.inputs[1] as usize]
            } else {
                false
            };
            values[g.out as usize] = g.kind.eval(a, b);
        }
        self.outputs
            .iter()
            .enumerate()
            .map(|(i, &n)| (values[n as usize] as u64) << i)
            .sum()
    }

    /// Per-gate nominal delays: `base_delay · (1 + σ·N(0,1))` process
    /// variation, in arbitrary units (the GLS calibrates the global scale
    /// against the clock period).
    pub fn gate_delays(&self, sigma: f64, rng: &mut Prng) -> Vec<f64> {
        self.gates
            .iter()
            .map(|g| {
                let var = (1.0 + sigma * rng.normal()).clamp(0.6, 1.6);
                g.kind.base_delay() * var
            })
            .collect()
    }

    /// Static longest path (in delay units) from any primary input to each
    /// net; `arrival[out]` for outputs is the critical path used to
    /// calibrate the GLS clock.
    pub fn arrival_times(&self, delays: &[f64]) -> Vec<f64> {
        let mut arr = vec![0.0f64; self.n_nets];
        for (gi, g) in self.gates.iter().enumerate() {
            let mut t = arr[g.inputs[0] as usize];
            if g.kind.n_inputs() == 2 {
                t = t.max(arr[g.inputs[1] as usize]);
            }
            arr[g.out as usize] = t + delays[gi];
        }
        arr
    }

    /// Critical path delay over all sum outputs.
    pub fn critical_path(&self, delays: &[f64]) -> f64 {
        let arr = self.arrival_times(delays);
        self.outputs
            .iter()
            .map(|&n| arr[n as usize])
            .fold(0.0, f64::max)
    }

    /// Per-output-bit structural depth (max gate count to that bit) —
    /// exposes the carry-chain asymmetry the error model exploits.
    pub fn output_depths(&self) -> Vec<usize> {
        let unit = vec![1.0f64; self.gates.len()];
        let arr = self.arrival_times(&unit);
        self.outputs
            .iter()
            .map(|&n| arr[n as usize] as usize)
            .collect()
    }

    /// Fan-out adjacency: for each net, the gate indices it drives (used
    /// by the event-driven simulator).
    pub fn fanout(&self) -> Vec<Vec<u32>> {
        let mut fo = vec![Vec::new(); self.n_nets];
        for (gi, g) in self.gates.iter().enumerate() {
            fo[g.inputs[0] as usize].push(gi as u32);
            if g.kind.n_inputs() == 2 && g.inputs[1] != g.inputs[0] {
                fo[g.inputs[1] as usize].push(gi as u32);
            }
        }
        fo
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;

    fn popcount_and(a: &[bool], w: &[bool]) -> u64 {
        a.iter().zip(w).filter(|(&x, &y)| x && y).count() as u64
    }

    #[test]
    fn ipe_computes_popcount_small_exhaustive() {
        // C=4: all 256 input combinations.
        let nl = build_ipe(4);
        for aw in 0u32..256 {
            let a: Vec<bool> = (0..4).map(|i| (aw >> i) & 1 == 1).collect();
            let w: Vec<bool> = (0..4).map(|i| (aw >> (4 + i)) & 1 == 1).collect();
            assert_eq!(nl.eval(&a, &w), popcount_and(&a, &w));
        }
    }

    #[test]
    fn ipe_computes_popcount_random() {
        check("ipe == popcount(AND)", 40, |rng| {
            let c = rng.int_in(1, 600) as usize;
            let nl = build_ipe(c);
            let a: Vec<bool> = (0..c).map(|_| rng.chance(0.5)).collect();
            let w: Vec<bool> = (0..c).map(|_| rng.chance(0.5)).collect();
            assert_eq!(nl.eval(&a, &w), popcount_and(&a, &w));
        });
    }

    #[test]
    fn output_width_matches_paper() {
        // C=576 -> 10-bit iPE outputs (paper §III).
        let nl = build_ipe(576);
        assert_eq!(nl.outputs.len(), 10);
        assert_eq!(build_ipe(36).outputs.len(), 6);
    }

    #[test]
    fn all_ones_saturates() {
        let c = 576;
        let nl = build_ipe(c);
        let ones = vec![true; c];
        assert_eq!(nl.eval(&ones, &ones), c as u64);
        let zeros = vec![false; c];
        assert_eq!(nl.eval(&ones, &zeros), 0);
    }

    #[test]
    fn msbs_are_structurally_deeper() {
        // The carry-chain asymmetry: depth must be non-decreasing-ish with
        // significance, and the MSB strictly deeper than the LSB.
        let nl = build_ipe(576);
        let d = nl.output_depths();
        assert!(
            d[9] > d[0] + 10,
            "MSB depth {} vs LSB depth {}",
            d[9],
            d[0]
        );
        // Monotone over the top half.
        for i in 5..9 {
            assert!(d[i + 1] >= d[i], "depth dip at bit {i}: {d:?}");
        }
    }

    #[test]
    fn gate_count_scales_linearly() {
        // ~11 gates per leaf for the AND + FA-tree structure.
        let n576 = build_ipe(576).gates.len();
        assert!(n576 > 4000 && n576 < 9000, "gate count {n576}");
        let n72 = build_ipe(72).gates.len();
        assert!((n576 as f64 / n72 as f64 - 8.0).abs() < 1.5);
    }

    #[test]
    fn critical_path_positive_and_msb_dominated() {
        let nl = build_ipe(576);
        let delays: Vec<f64> = nl.gates.iter().map(|g| g.kind.base_delay()).collect();
        let arr = nl.arrival_times(&delays);
        let out_arr: Vec<f64> = nl.outputs.iter().map(|&n| arr[n as usize]).collect();
        let cp = nl.critical_path(&delays);
        assert!(cp > 0.0);
        assert_eq!(cp, out_arr.iter().cloned().fold(0.0, f64::max));
        // The critical path terminates at one of the top 2 bits.
        let imax = out_arr
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        assert!(imax >= 8, "critical output bit {imax}");
    }

    #[test]
    fn fanout_consistent() {
        let nl = build_ipe(36);
        let fo = nl.fanout();
        // Every gate appears in the fanout of each of its inputs.
        for (gi, g) in nl.gates.iter().enumerate() {
            assert!(fo[g.inputs[0] as usize].contains(&(gi as u32)));
        }
        // Output nets drive nothing.
        for &o in &nl.outputs {
            assert!(fo[o as usize].is_empty());
        }
    }
}
