//! The real PJRT runtime (feature `pjrt`): compiles `artifacts/*.hlo.txt`
//! on the `xla` crate's CPU client, with a per-artifact executable cache.
//! Requires the `xla` and `anyhow` crates to be vendored into the build.

use anyhow::{anyhow, Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};

use crate::arch::Precision;

/// A loaded artifact manifest entry.
#[derive(Clone, Debug)]
pub struct ManifestEntry {
    pub name: String,
    pub signature: String,
}

/// PJRT runtime with a compiled-executable cache.
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    cache: HashMap<String, xla::PjRtLoadedExecutable>,
    pub manifest: Vec<ManifestEntry>,
}

impl Runtime {
    /// Create a CPU PJRT client over an artifacts directory.
    pub fn new(artifacts_dir: &Path) -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        let manifest_path = artifacts_dir.join("manifest.txt");
        let manifest = if manifest_path.exists() {
            std::fs::read_to_string(&manifest_path)?
                .lines()
                .filter_map(|l| {
                    let (name, sig) = l.split_once('\t')?;
                    Some(ManifestEntry {
                        name: name.to_string(),
                        signature: sig.to_string(),
                    })
                })
                .collect()
        } else {
            Vec::new()
        };
        Ok(Self {
            client,
            dir: artifacts_dir.to_path_buf(),
            cache: HashMap::new(),
            manifest,
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (or fetch from cache) an artifact by file name.
    pub fn load(&mut self, name: &str) -> Result<&xla::PjRtLoadedExecutable> {
        if !self.cache.contains_key(name) {
            let path = self.dir.join(name);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
            )
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .with_context(|| format!("compiling {name}"))?;
            self.cache.insert(name.to_string(), exe);
        }
        Ok(&self.cache[name])
    }

    /// Execute an artifact on f32 inputs (each `(data, dims)`), returning
    /// the flattened f32 output (AOT functions are lowered with
    /// `return_tuple=True`, so the result is unwrapped from a 1-tuple).
    pub fn execute_f32(&mut self, name: &str, inputs: &[(&[f32], &[usize])]) -> Result<Vec<f32>> {
        let lits = inputs
            .iter()
            .map(|(data, dims)| {
                let dims_i64: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
                xla::Literal::vec1(data).reshape(&dims_i64)
            })
            .collect::<std::result::Result<Vec<_>, _>>()?;
        let exe = self.load(name)?;
        let result = exe.execute::<xla::Literal>(&lits)?[0][0].to_literal_sync()?;
        let out = result.to_tuple1()?;
        Ok(out.to_vec::<f32>()?)
    }

    /// Run the AOT bit-serial GEMM of one hardware tile: bit-planes in
    /// (`{0,1}` f32, shapes `[a_bits, C, L]` / `[b_bits, K, C]`), integer
    /// GEMM out (`[K, L]`, i32 carried as f32 by the artifact wrapper).
    pub fn bitserial_gemm_tile(
        &mut self,
        prec: Precision,
        a_planes: &[f32],
        b_planes: &[f32],
        c_dim: usize,
        l_dim: usize,
        k_dim: usize,
    ) -> Result<Vec<i32>> {
        let name = format!("bitserial_gemm_a{}w{}.hlo.txt", prec.a_bits, prec.b_bits);
        let a_dims = [prec.a_bits as usize, c_dim, l_dim];
        let b_dims = [prec.b_bits as usize, k_dim, c_dim];
        let lit_a = {
            let d: Vec<i64> = a_dims.iter().map(|&x| x as i64).collect();
            xla::Literal::vec1(a_planes).reshape(&d)?
        };
        let lit_b = {
            let d: Vec<i64> = b_dims.iter().map(|&x| x as i64).collect();
            xla::Literal::vec1(b_planes).reshape(&d)?
        };
        let exe = self.load(&name)?;
        let result = exe.execute::<xla::Literal>(&[lit_a, lit_b])?[0][0].to_literal_sync()?;
        let out = result.to_tuple1()?;
        Ok(out.to_vec::<i32>()?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::PackedPlanes;
    use crate::util::Prng;

    fn artifacts_dir() -> PathBuf {
        Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    fn have_artifacts() -> bool {
        artifacts_dir().join("manifest.txt").exists()
    }

    #[test]
    fn manifest_loads() {
        if !have_artifacts() {
            eprintln!("skipping: no artifacts (run `make artifacts`)");
            return;
        }
        let rt = Runtime::new(&artifacts_dir()).unwrap();
        assert!(rt.manifest.len() >= 9, "manifest: {:?}", rt.manifest.len());
        assert!(rt.platform().to_lowercase().contains("cpu"));
    }

    #[test]
    fn binary_plane_artifact_matches_rust_gemm() {
        if !have_artifacts() {
            return;
        }
        let mut rt = Runtime::new(&artifacts_dir()).unwrap();
        let (c, l, k) = (576, 8, 16);
        let mut rng = Prng::new(3);
        let a: Vec<f32> = (0..c * l).map(|_| (rng.chance(0.5) as u32) as f32).collect();
        let b: Vec<f32> = (0..k * c).map(|_| (rng.chance(0.5) as u32) as f32).collect();
        let out = rt
            .execute_f32("binary_plane.hlo.txt", &[(&a, &[c, l]), (&b, &[k, c])])
            .unwrap();
        assert_eq!(out.len(), k * l);
        // Reference: popcount(AND) == {0,1} matmul.
        for ki in 0..k {
            for li in 0..l {
                let mut acc = 0.0f32;
                for ci in 0..c {
                    acc += a[ci * l + li] * b[ki * c + ci];
                }
                assert_eq!(out[ki * l + li], acc, "({ki},{li})");
            }
        }
    }

    #[test]
    fn bitserial_tile_artifact_matches_rust_bitserial() {
        if !have_artifacts() {
            return;
        }
        let mut rt = Runtime::new(&artifacts_dir()).unwrap();
        let (c, l, k) = (576, 8, 16);
        let prec = Precision::new(4, 4);
        let mut rng = Prng::new(4);
        let a: Vec<i32> = (0..c * l).map(|_| rng.int_in(-8, 7) as i32).collect();
        let b: Vec<i32> = (0..k * c).map(|_| rng.int_in(-8, 7) as i32).collect();
        let pa = PackedPlanes::from_a_matrix(&a, c, l, 4);
        let pb = PackedPlanes::from_b_matrix(&b, k, c, 4);

        // Unpack planes to the artifact's dense {0,1} layout.
        let mut a_planes = Vec::with_capacity(4 * c * l);
        for plane in 0..4 {
            // artifact wants [C, L]: transpose of unpack_plane's [L, C].
            let dense = pa.unpack_plane(plane); // [l, c]
            for ci in 0..c {
                for li in 0..l {
                    a_planes.push(dense[li * c + ci]);
                }
            }
        }
        let mut b_planes = Vec::with_capacity(4 * k * c);
        for plane in 0..4 {
            b_planes.extend_from_slice(&pb.unpack_plane(plane)); // [k, c]
        }

        let out = rt
            .bitserial_gemm_tile(prec, &a_planes, &b_planes, c, l, k)
            .unwrap();
        let expect = crate::gemm::bitserial_gemm(&pa, &pb);
        assert_eq!(out.len(), expect.len());
        for (o, e) in out.iter().zip(&expect) {
            assert_eq!(*o as i64, *e);
        }
    }
}
