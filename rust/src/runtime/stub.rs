//! Std-only stand-in for the PJRT runtime: same API surface as
//! [`super::pjrt`], but every entry point reports that the `pjrt` feature
//! is disabled. Keeps `gavina selfcheck` and the artifact cross-check
//! tests compiling (they skip when the runtime is unavailable).

use std::path::Path;

use crate::arch::Precision;

/// Error carried by every stub entry point.
#[derive(Clone, Debug)]
pub struct RuntimeError(String);

impl std::fmt::Display for RuntimeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for RuntimeError {}

fn unavailable() -> RuntimeError {
    RuntimeError(
        "PJRT runtime disabled: rebuild with `--features pjrt` (requires vendored `xla` + \
         `anyhow` crates)"
            .to_string(),
    )
}

/// A loaded artifact manifest entry (mirrors the `pjrt` build).
#[derive(Clone, Debug)]
pub struct ManifestEntry {
    pub name: String,
    pub signature: String,
}

/// Stub runtime: construction always fails with a clear message.
pub struct Runtime {
    pub manifest: Vec<ManifestEntry>,
}

impl Runtime {
    /// Always returns `Err`: the std-only build cannot execute artifacts.
    pub fn new(_artifacts_dir: &Path) -> Result<Self, RuntimeError> {
        Err(unavailable())
    }

    pub fn platform(&self) -> String {
        "unavailable (built without the `pjrt` feature)".to_string()
    }

    /// Mirrors `pjrt::Runtime::execute_f32`; always `Err` here.
    pub fn execute_f32(
        &mut self,
        _name: &str,
        _inputs: &[(&[f32], &[usize])],
    ) -> Result<Vec<f32>, RuntimeError> {
        Err(unavailable())
    }

    /// Mirrors `pjrt::Runtime::bitserial_gemm_tile`; always `Err` here.
    pub fn bitserial_gemm_tile(
        &mut self,
        _prec: Precision,
        _a_planes: &[f32],
        _b_planes: &[f32],
        _c_dim: usize,
        _l_dim: usize,
        _k_dim: usize,
    ) -> Result<Vec<i32>, RuntimeError> {
        Err(unavailable())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_reports_missing_feature() {
        let err = Runtime::new(Path::new("artifacts")).err().expect("stub");
        assert!(err.to_string().contains("pjrt"));
    }
}
