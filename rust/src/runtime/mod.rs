//! PJRT runtime: loads the AOT-lowered HLO artifacts (`artifacts/*.hlo.txt`,
//! produced once by `make artifacts` from the JAX/Pallas layers) and
//! executes them on the `xla` crate's CPU client.
//!
//! Python never runs on this path: the HLO **text** is the interchange
//! format (xla_extension 0.5.1 rejects jax≥0.5 serialized protos — 64-bit
//! instruction ids; the text parser reassigns ids). See
//! `/opt/xla-example/README.md` and `python/compile/aot.py`.
//!
//! The crate is std-only by default (DESIGN.md §Substitutions), so the
//! real client lives behind the `pjrt` feature — which requires vendoring
//! the `xla` and `anyhow` crates. Without it this module compiles to a
//! stub whose constructor reports the feature is missing; every consumer
//! (the `selfcheck` subcommand, the artifact-backed tests) already treats
//! "runtime unavailable" as a skip.

// API note: `RuntimeError` exists only in the stub build — the `pjrt`
// build returns `anyhow::Result`. Only `Display` on the error is stable
// across both; match on the message, not the concrete type.
#[cfg(not(feature = "pjrt"))]
mod stub;
#[cfg(not(feature = "pjrt"))]
pub use stub::{ManifestEntry, Runtime, RuntimeError};

#[cfg(feature = "pjrt")]
mod pjrt;
#[cfg(feature = "pjrt")]
pub use pjrt::{ManifestEntry, Runtime};
