//! Serving coordinator: the Layer-3 request loop that turns the GAVINA
//! stack into a deployable inference service.
//!
//! Architecture (std threads + channels; the vendored crate set has no
//! async runtime, and the workload is CPU-bound anyway):
//!
//! ```text
//! clients ──▶ batcher thread ──▶ worker pool (N threads) ──▶ responses
//!              (size/deadline       each shares one Arc<Engine>
//!               batching)           over weights+tables)
//! ```
//!
//! * The **batcher** groups single-image requests into GAVINA-sized
//!   batches (bounded by `max_batch` or `batch_timeout`), because the
//!   accelerator amortizes its A0/B0 plane streams over the `L` dimension.
//! * **Workers** run the quantized forward pass through a shared
//!   [`Engine`] (its [`GavPolicy`](crate::engine::GavPolicy) decides the
//!   per-layer G allocation; its `threads` knob parallelizes *inside* a
//!   batch, while `workers` parallelizes *across* batches). A malformed
//!   request gets a per-request error [`Response`] — workers never die on
//!   bad input.
//! * **Metrics** track end-to-end latency percentiles (bounded
//!   reservoir), throughput, and the accelerator-side counters (simulated
//!   cycles, energy, corrupted values) — the numbers the `serve` example
//!   reports.
//!
//! Start a service with [`Engine::serve`] and [`ServeOptions`].

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::arch::GavSchedule;
use crate::config::Config;
use crate::dnn::IMAGE_LEN;
use crate::engine::{Engine, GavinaError};
use crate::power::PowerModel;

/// One inference request (a single 32×32×3 image).
pub struct Request {
    pub image: Vec<f32>,
    pub submitted: Instant,
    pub resp: Sender<Response>,
}

/// The response: class logits (or a typed error) plus tracing info.
#[derive(Clone, Debug)]
pub struct Response {
    /// Logits on success; a [`GavinaError`] when this request (or its
    /// batch) could not be executed. The service stays up either way.
    pub result: Result<Vec<f32>, GavinaError>,
    pub latency: Duration,
    pub batch_size: usize,
}

impl Response {
    /// The logits, or a panic with the typed error (tests / demos).
    pub fn expect_logits(self, msg: &str) -> Vec<f32> {
        match self.result {
            Ok(l) => l,
            Err(e) => panic!("{msg}: {e}"),
        }
    }
}

/// Service configuration: the knobs of the batching layer. Everything
/// model/accelerator-side (precision, G policy, error tables, intra-batch
/// threads) lives on the [`Engine`].
#[derive(Clone, Debug)]
pub struct ServeOptions {
    /// Batch worker threads (each drains whole batches).
    pub workers: usize,
    /// Largest batch handed to one worker.
    pub max_batch: usize,
    /// Deadline after which a partial batch is flushed.
    pub batch_timeout: Duration,
}

impl Default for ServeOptions {
    fn default() -> Self {
        Self {
            workers: 2,
            max_batch: 8,
            batch_timeout: Duration::from_millis(20),
        }
    }
}

impl ServeOptions {
    /// Load from the `[serve]` section of a parsed config. Recognized
    /// keys: `workers`, `max_batch`, `batch_timeout_ms`; unknown
    /// `serve.*` keys are a [`GavinaError::Config`].
    pub fn from_config(cfg: &Config) -> Result<Self, GavinaError> {
        const KNOWN: &[&str] = &["workers", "max_batch", "batch_timeout_ms"];
        for (key, _) in cfg.keys_with_prefix("serve.") {
            if !KNOWN.contains(&key) {
                return Err(GavinaError::Config(format!(
                    "unknown [serve] key '{key}' (known: {})",
                    KNOWN.join(", ")
                )));
            }
        }
        let d = Self::default();
        let int = |key: &str, default: i64| -> Result<i64, GavinaError> {
            match cfg.get(key) {
                None => Ok(default),
                Some(v) => v
                    .as_int()
                    .filter(|&i| i >= 1)
                    .ok_or_else(|| GavinaError::Config(format!("{key} must be an integer ≥ 1"))),
            }
        };
        Ok(Self {
            workers: int("serve.workers", d.workers as i64)? as usize,
            max_batch: int("serve.max_batch", d.max_batch as i64)? as usize,
            batch_timeout: Duration::from_millis(int(
                "serve.batch_timeout_ms",
                d.batch_timeout.as_millis() as i64,
            )? as u64),
        })
    }
}

/// Latency reservoir capacity: percentiles are computed over a uniform
/// sample of at most this many observations, so a long-running service
/// holds O(1) memory instead of one `u64` per request ever served.
const LATENCY_RESERVOIR: usize = 4096;

/// Uniform reservoir sample of latency observations (Vitter's Algorithm
/// R with a cheap xorshift index source — metrics, not cryptography).
struct Reservoir {
    buf: Vec<u64>,
    seen: u64,
    rng: u64,
}

impl Reservoir {
    fn new() -> Self {
        Self {
            buf: Vec::new(),
            seen: 0,
            rng: 0x9E37_79B9_7F4A_7C15,
        }
    }

    fn push(&mut self, v: u64) {
        self.seen += 1;
        if self.buf.len() < LATENCY_RESERVOIR {
            self.buf.push(v);
            return;
        }
        self.rng ^= self.rng << 13;
        self.rng ^= self.rng >> 7;
        self.rng ^= self.rng << 17;
        let j = self.rng % self.seen;
        if (j as usize) < LATENCY_RESERVOIR {
            self.buf[j as usize] = v;
        }
    }
}

/// Aggregated service metrics.
pub struct Metrics {
    pub requests: AtomicU64,
    pub batches: AtomicU64,
    /// Requests rejected with an error [`Response`] (bad shape, backend
    /// failure).
    pub errors: AtomicU64,
    pub sim_cycles: AtomicU64,
    pub corrupted: AtomicU64,
    latencies_us: Mutex<Reservoir>,
    /// Running true maximum — the one statistic a uniform reservoir
    /// systematically loses once eviction starts.
    max_latency_us: AtomicU64,
    started: Instant,
    last_record: Mutex<Option<Instant>>,
}

impl Metrics {
    fn new() -> Self {
        Self {
            requests: AtomicU64::new(0),
            batches: AtomicU64::new(0),
            errors: AtomicU64::new(0),
            sim_cycles: AtomicU64::new(0),
            corrupted: AtomicU64::new(0),
            latencies_us: Mutex::new(Reservoir::new()),
            max_latency_us: AtomicU64::new(0),
            started: Instant::now(),
            last_record: Mutex::new(None),
        }
    }

    fn record(&self, n_req: usize, lat: &[Duration], cycles: u64, corrupted: u64) {
        self.requests.fetch_add(n_req as u64, Ordering::Relaxed);
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.sim_cycles.fetch_add(cycles, Ordering::Relaxed);
        self.corrupted.fetch_add(corrupted, Ordering::Relaxed);
        {
            let mut l = self.latencies_us.lock().unwrap();
            for d in lat {
                let us = d.as_micros() as u64;
                self.max_latency_us.fetch_max(us, Ordering::Relaxed);
                l.push(us);
            }
        }
        *self.last_record.lock().unwrap() = Some(Instant::now());
    }

    fn record_errors(&self, n: usize) {
        self.errors.fetch_add(n as u64, Ordering::Relaxed);
        *self.last_record.lock().unwrap() = Some(Instant::now());
    }

    /// (p50, p95, max) latency in microseconds. The percentiles come
    /// from the bounded reservoir sample; the max is the exact running
    /// maximum over every recorded request.
    pub fn latency_percentiles(&self) -> (u64, u64, u64) {
        let mut l = {
            // Copy only the bounded reservoir (≤ LATENCY_RESERVOIR), never
            // an unbounded history.
            self.latencies_us.lock().unwrap().buf.clone()
        };
        if l.is_empty() {
            return (0, 0, 0);
        }
        l.sort_unstable();
        let pick = |q: f64| l[((l.len() - 1) as f64 * q) as usize];
        (
            pick(0.50),
            pick(0.95),
            self.max_latency_us.load(Ordering::Relaxed),
        )
    }

    /// Served requests per second, from coordinator start to the last
    /// recorded batch (0.0 before anything completes).
    pub fn requests_per_sec(&self) -> f64 {
        let last = *self.last_record.lock().unwrap();
        match last {
            Some(t) => {
                let secs = t.duration_since(self.started).as_secs_f64();
                if secs > 0.0 {
                    self.requests.load(Ordering::Relaxed) as f64 / secs
                } else {
                    0.0
                }
            }
            None => 0.0,
        }
    }

    /// Accelerator-side energy for the served traffic [mJ].
    pub fn energy_mj(&self, power: &PowerModel, sched: &GavSchedule) -> f64 {
        power.energy_mj(sched, self.sim_cycles.load(Ordering::Relaxed))
    }
}

enum BatcherMsg {
    Req(Request),
    Shutdown,
}

/// The running service.
pub struct Coordinator {
    tx: Sender<BatcherMsg>,
    pub metrics: Arc<Metrics>,
    batcher: Option<std::thread::JoinHandle<()>>,
    workers: Vec<std::thread::JoinHandle<()>>,
}

impl Coordinator {
    /// Start the batcher + worker pool over a shared engine (also
    /// reachable as [`Engine::serve`]).
    pub fn start(engine: Arc<Engine>, opts: ServeOptions) -> Self {
        let metrics = Arc::new(Metrics::new());
        let (tx, rx) = channel::<BatcherMsg>();
        let (work_tx, work_rx) = channel::<Vec<Request>>();
        let work_rx = Arc::new(Mutex::new(work_rx));

        // Worker pool.
        let mut workers = Vec::new();
        for wi in 0..opts.workers.max(1) {
            let work_rx = Arc::clone(&work_rx);
            let engine = Arc::clone(&engine);
            let metrics = Arc::clone(&metrics);
            workers.push(std::thread::spawn(move || {
                loop {
                    let batch = {
                        let rx = work_rx.lock().unwrap();
                        rx.recv()
                    };
                    let Ok(batch) = batch else { break };
                    if batch.is_empty() {
                        break;
                    }
                    run_batch(&engine, wi as u64, &metrics, batch);
                }
            }));
        }

        // Batcher.
        let batcher_opts = opts.clone();
        let batcher = std::thread::spawn(move || {
            let mut pending: Vec<Request> = Vec::new();
            let mut deadline: Option<Instant> = None;
            loop {
                let timeout = deadline
                    .map(|d| d.saturating_duration_since(Instant::now()))
                    .unwrap_or(Duration::from_secs(3600));
                match rx.recv_timeout(timeout) {
                    Ok(BatcherMsg::Req(r)) => {
                        if pending.is_empty() {
                            deadline = Some(Instant::now() + batcher_opts.batch_timeout);
                        }
                        pending.push(r);
                        if pending.len() >= batcher_opts.max_batch {
                            let _ = work_tx.send(std::mem::take(&mut pending));
                            deadline = None;
                        }
                    }
                    Ok(BatcherMsg::Shutdown) => {
                        if !pending.is_empty() {
                            let _ = work_tx.send(std::mem::take(&mut pending));
                        }
                        // Poison the pool: one empty batch per worker.
                        for _ in 0..batcher_opts.workers.max(1) {
                            let _ = work_tx.send(Vec::new());
                        }
                        break;
                    }
                    Err(std::sync::mpsc::RecvTimeoutError::Timeout) => {
                        if !pending.is_empty() {
                            let _ = work_tx.send(std::mem::take(&mut pending));
                        }
                        deadline = None;
                    }
                    Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => break,
                }
            }
        });

        Self {
            tx,
            metrics,
            batcher: Some(batcher),
            workers,
        }
    }

    /// Submit one image; returns the response receiver.
    pub fn submit(&self, image: Vec<f32>) -> Receiver<Response> {
        let (resp_tx, resp_rx) = channel();
        let _ = self.tx.send(BatcherMsg::Req(Request {
            image,
            submitted: Instant::now(),
            resp: resp_tx,
        }));
        resp_rx
    }

    /// Drain and stop all threads.
    pub fn shutdown(mut self) -> Arc<Metrics> {
        let _ = self.tx.send(BatcherMsg::Shutdown);
        if let Some(b) = self.batcher.take() {
            let _ = b.join();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
        Arc::clone(&self.metrics)
    }
}

fn run_batch(engine: &Engine, worker_id: u64, metrics: &Metrics, batch: Vec<Request>) {
    // Malformed requests get an error Response and never reach the
    // executor; the rest of the batch proceeds normally. Worker threads
    // must survive arbitrary client input.
    let (good, bad): (Vec<Request>, Vec<Request>) = batch
        .into_iter()
        .partition(|r| r.image.len() == IMAGE_LEN);
    // Every response from one physical batch reports the same
    // batch_size: the number of requests that actually executed.
    let n = good.len();
    if !bad.is_empty() {
        metrics.record_errors(bad.len());
        for r in bad {
            let latency = r.submitted.elapsed();
            let _ = r.resp.send(Response {
                result: Err(GavinaError::Shape {
                    what: "request image".into(),
                    expected: IMAGE_LEN,
                    got: r.image.len(),
                }),
                latency,
                batch_size: n,
            });
        }
    }
    if good.is_empty() {
        return;
    }
    let mut images = Vec::with_capacity(n * IMAGE_LEN);
    for r in &good {
        images.extend_from_slice(&r.image);
    }
    match engine.infer_parallel(&images, n, worker_id.wrapping_mul(0xD1F)) {
        Ok(result) => {
            let now = Instant::now();
            let classes = result.classes;
            let mut lats = Vec::with_capacity(n);
            for (i, r) in good.into_iter().enumerate() {
                let latency = now.duration_since(r.submitted);
                lats.push(latency);
                let _ = r.resp.send(Response {
                    result: Ok(result.logits[i * classes..(i + 1) * classes].to_vec()),
                    latency,
                    batch_size: n,
                });
            }
            metrics.record(n, &lats, result.stats.cycles, result.stats.corrupted);
        }
        Err(e) => {
            // Shouldn't happen (shapes were validated above), but a
            // failing backend must not kill the worker either.
            metrics.record_errors(n);
            for r in good {
                let latency = r.submitted.elapsed();
                let _ = r.resp.send(Response {
                    result: Err(e.clone()),
                    latency,
                    batch_size: n,
                });
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::{ArchConfig, Precision};
    use crate::engine::{EngineBuilder, GavPolicy};
    use crate::util::Prng;

    fn small_engine(threads: usize) -> Arc<Engine> {
        Arc::new(
            EngineBuilder::new()
                .synthetic_weights(0.125, 1)
                .precision(Precision::new(2, 2))
                .arch(ArchConfig::tiny())
                .policy(GavPolicy::Exact)
                .seed(1)
                .threads(threads)
                .build()
                .unwrap(),
        )
    }

    fn small_opts() -> ServeOptions {
        ServeOptions {
            workers: 2,
            max_batch: 4,
            batch_timeout: Duration::from_millis(5),
        }
    }

    fn rand_image(rng: &mut Prng) -> Vec<f32> {
        (0..IMAGE_LEN).map(|_| rng.next_f32()).collect()
    }

    #[test]
    fn serves_requests_end_to_end() {
        let coord = small_engine(1).serve(small_opts());
        let mut rng = Prng::new(2);
        let mut rxs = Vec::new();
        for _ in 0..10 {
            rxs.push(coord.submit(rand_image(&mut rng)));
        }
        for rx in rxs {
            let resp = rx.recv_timeout(Duration::from_secs(120)).expect("response");
            assert!(resp.batch_size >= 1 && resp.batch_size <= 4);
            let logits = resp.expect_logits("good request");
            assert_eq!(logits.len(), 10);
            assert!(logits.iter().all(|v| v.is_finite()));
        }
        let m = coord.shutdown();
        assert_eq!(m.requests.load(Ordering::Relaxed), 10);
        assert_eq!(m.errors.load(Ordering::Relaxed), 0);
        assert!(m.batches.load(Ordering::Relaxed) >= 3); // max_batch 4
        assert!(m.sim_cycles.load(Ordering::Relaxed) > 0);
        let (p50, p95, max) = m.latency_percentiles();
        assert!(p50 > 0 && p95 >= p50 && max >= p95);
        assert!(m.requests_per_sec() > 0.0);
    }

    #[test]
    fn bad_request_gets_error_response_and_workers_survive() {
        // The old coordinator asserted on image length, killing the worker
        // thread; now the short image gets a typed error Response and the
        // 10 well-formed requests around it are all still served.
        let coord = small_engine(1).serve(small_opts());
        let mut rng = Prng::new(3);
        let mut good = Vec::new();
        for _ in 0..3 {
            good.push(coord.submit(rand_image(&mut rng)));
        }
        let bad_rx = coord.submit(vec![0.5; 100]); // short image
        for _ in 0..7 {
            good.push(coord.submit(rand_image(&mut rng)));
        }
        let bad = bad_rx
            .recv_timeout(Duration::from_secs(120))
            .expect("error response");
        match bad.result {
            Err(GavinaError::Shape { expected, got, .. }) => {
                assert_eq!(expected, IMAGE_LEN);
                assert_eq!(got, 100);
            }
            other => panic!("expected shape error, got {other:?}"),
        }
        for rx in good {
            let resp = rx.recv_timeout(Duration::from_secs(120)).expect("response");
            assert_eq!(resp.expect_logits("good request").len(), 10);
        }
        let m = coord.shutdown();
        assert_eq!(m.requests.load(Ordering::Relaxed), 10);
        assert_eq!(m.errors.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn batching_respects_max_batch() {
        let mut opts = small_opts();
        opts.max_batch = 2;
        let coord = small_engine(1).serve(opts);
        let mut rng = Prng::new(4);
        let rxs: Vec<_> = (0..6).map(|_| coord.submit(rand_image(&mut rng))).collect();
        for rx in rxs {
            let resp = rx.recv_timeout(Duration::from_secs(120)).unwrap();
            assert!(resp.batch_size <= 2);
        }
        coord.shutdown();
    }

    #[test]
    fn intra_batch_threads_serve_end_to_end() {
        let mut opts = small_opts();
        opts.max_batch = 6;
        let coord = small_engine(2).serve(opts);
        let mut rng = Prng::new(12);
        let rxs: Vec<_> = (0..9).map(|_| coord.submit(rand_image(&mut rng))).collect();
        for rx in rxs {
            let resp = rx.recv_timeout(Duration::from_secs(120)).expect("response");
            let logits = resp.expect_logits("good request");
            assert_eq!(logits.len(), 10);
            assert!(logits.iter().all(|v| v.is_finite()));
        }
        let m = coord.shutdown();
        assert_eq!(m.requests.load(Ordering::Relaxed), 9);
    }

    #[test]
    fn shutdown_flushes_pending() {
        let mut opts = small_opts();
        opts.max_batch = 64; // never reached
        opts.batch_timeout = Duration::from_secs(3600); // never fires
        let coord = small_engine(1).serve(opts);
        let mut rng = Prng::new(6);
        let rx = coord.submit(rand_image(&mut rng));
        // Shutdown must flush the pending (sub-batch) request.
        let m_handle = std::thread::spawn(move || coord.shutdown());
        let resp = rx.recv_timeout(Duration::from_secs(120)).expect("flushed");
        assert_eq!(resp.expect_logits("flushed request").len(), 10);
        m_handle.join().unwrap();
    }

    #[test]
    fn reservoir_bounds_memory_and_keeps_percentiles_sane() {
        let mut r = Reservoir::new();
        for i in 0..(LATENCY_RESERVOIR as u64 * 4) {
            r.push(i);
        }
        assert_eq!(r.buf.len(), LATENCY_RESERVOIR);
        assert_eq!(r.seen, LATENCY_RESERVOIR as u64 * 4);
        // The sample must span the observed range, not just the prefix.
        assert!(r.buf.iter().any(|&v| v >= LATENCY_RESERVOIR as u64));
    }

    #[test]
    fn serve_options_from_config_rejects_unknown_keys() {
        let cfg = crate::config::parse("[serve]\nworkers = 3\nmax_batch = 16\n").unwrap();
        let opts = ServeOptions::from_config(&cfg).unwrap();
        assert_eq!(opts.workers, 3);
        assert_eq!(opts.max_batch, 16);
        assert_eq!(opts.batch_timeout, Duration::from_millis(20));

        let cfg = crate::config::parse("[serve]\nworker = 3\n").unwrap();
        let err = ServeOptions::from_config(&cfg).unwrap_err();
        assert!(err.to_string().contains("unknown [serve] key"), "{err}");

        let cfg = crate::config::parse("[serve]\nmax_batch = 0\n").unwrap();
        assert!(ServeOptions::from_config(&cfg).is_err());
    }
}
