//! Serving coordinator: the Layer-3 request loop that turns the GAVINA
//! stack into a deployable inference service.
//!
//! Architecture (std threads + channels; the vendored crate set has no
//! async runtime, and the workload is CPU-bound anyway):
//!
//! ```text
//! clients ──▶ batcher thread ──▶ worker pool (N threads) ──▶ responses
//!              (size/deadline       each owns an Executor
//!               batching)           over shared weights+tables)
//! ```
//!
//! * The **batcher** groups single-image requests into GAVINA-sized
//!   batches (bounded by `max_batch` or `batch_timeout`), because the
//!   accelerator amortizes its A0/B0 plane streams over the `L` dimension.
//! * **Workers** run the quantized forward pass on the cycle-level
//!   simulator backend with the service's GAV configuration (per-layer G
//!   allocation from the ILP, or a uniform G).
//! * **Metrics** track end-to-end latency percentiles, throughput, and
//!   the accelerator-side counters (simulated cycles, energy, corrupted
//!   values) — the numbers the `serve` example reports.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::arch::{ArchConfig, GavSchedule, Precision};
use crate::dnn::{Backend, Executor, ForwardResult, ForwardStats, TensorMap};
use crate::errmodel::ErrorTables;
use crate::power::PowerModel;
use crate::util::parallel;

/// One inference request (a single 32×32×3 image).
pub struct Request {
    pub image: Vec<f32>,
    pub submitted: Instant,
    pub resp: Sender<Response>,
}

/// The response: class logits plus tracing info.
#[derive(Clone, Debug)]
pub struct Response {
    pub logits: Vec<f32>,
    pub latency: Duration,
    pub batch_size: usize,
}

/// Service configuration.
#[derive(Clone)]
pub struct ServeConfig {
    pub arch: ArchConfig,
    pub precision: Precision,
    /// Per-layer G allocation (length = number of conv layers).
    pub layer_gs: Vec<u32>,
    pub width_mult: f64,
    pub workers: usize,
    /// Intra-batch worker threads: a batch of independent requests is
    /// split into contiguous sub-batches executed on scoped threads
    /// (`1` = serial, `0` = one per available core). Composes with
    /// `workers`, which parallelizes *across* batches.
    pub threads: usize,
    pub max_batch: usize,
    pub batch_timeout: Duration,
    pub seed: u64,
}

impl ServeConfig {
    pub fn new(precision: Precision, uniform_g: u32) -> Self {
        Self {
            arch: ArchConfig::paper(),
            precision,
            layer_gs: vec![uniform_g; crate::dnn::conv_layer_names().len()],
            width_mult: 0.25,
            workers: 2,
            threads: 1,
            max_batch: 8,
            batch_timeout: Duration::from_millis(20),
            seed: 7,
        }
    }
}

/// Aggregated service metrics.
#[derive(Default)]
pub struct Metrics {
    pub requests: AtomicU64,
    pub batches: AtomicU64,
    pub sim_cycles: AtomicU64,
    pub corrupted: AtomicU64,
    latencies_us: Mutex<Vec<u64>>,
}

impl Metrics {
    fn record(&self, n_req: usize, lat: &[Duration], cycles: u64, corrupted: u64) {
        self.requests.fetch_add(n_req as u64, Ordering::Relaxed);
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.sim_cycles.fetch_add(cycles, Ordering::Relaxed);
        self.corrupted.fetch_add(corrupted, Ordering::Relaxed);
        let mut l = self.latencies_us.lock().unwrap();
        l.extend(lat.iter().map(|d| d.as_micros() as u64));
    }

    /// (p50, p95, max) latency in microseconds.
    pub fn latency_percentiles(&self) -> (u64, u64, u64) {
        let mut l = self.latencies_us.lock().unwrap().clone();
        if l.is_empty() {
            return (0, 0, 0);
        }
        l.sort_unstable();
        let pick = |q: f64| l[((l.len() - 1) as f64 * q) as usize];
        (pick(0.50), pick(0.95), *l.last().unwrap())
    }

    /// Accelerator-side energy for the served traffic [mJ].
    pub fn energy_mj(&self, power: &PowerModel, sched: &GavSchedule) -> f64 {
        power.energy_mj(sched, self.sim_cycles.load(Ordering::Relaxed))
    }
}

enum BatcherMsg {
    Req(Request),
    Shutdown,
}

/// The running service.
pub struct Coordinator {
    tx: Sender<BatcherMsg>,
    pub metrics: Arc<Metrics>,
    batcher: Option<std::thread::JoinHandle<()>>,
    workers: Vec<std::thread::JoinHandle<()>>,
}

impl Coordinator {
    /// Start the batcher + worker pool over shared weights and calibrated
    /// error tables.
    pub fn start(
        cfg: ServeConfig,
        weights: Arc<TensorMap>,
        tables: Option<Arc<ErrorTables>>,
    ) -> Self {
        let metrics = Arc::new(Metrics::default());
        let (tx, rx) = channel::<BatcherMsg>();
        let (work_tx, work_rx) = channel::<Vec<Request>>();
        let work_rx = Arc::new(Mutex::new(work_rx));

        // Worker pool.
        let mut workers = Vec::new();
        for wi in 0..cfg.workers.max(1) {
            let work_rx = Arc::clone(&work_rx);
            let weights = Arc::clone(&weights);
            let tables = tables.clone();
            let metrics = Arc::clone(&metrics);
            let cfg = cfg.clone();
            workers.push(std::thread::spawn(move || {
                loop {
                    let batch = {
                        let rx = work_rx.lock().unwrap();
                        rx.recv()
                    };
                    let Ok(batch) = batch else { break };
                    if batch.is_empty() {
                        break;
                    }
                    run_batch(&cfg, wi as u64, &weights, tables.as_deref(), &metrics, batch);
                }
            }));
        }

        // Batcher.
        let batcher_cfg = cfg.clone();
        let batcher = std::thread::spawn(move || {
            let mut pending: Vec<Request> = Vec::new();
            let mut deadline: Option<Instant> = None;
            loop {
                let timeout = deadline
                    .map(|d| d.saturating_duration_since(Instant::now()))
                    .unwrap_or(Duration::from_secs(3600));
                match rx.recv_timeout(timeout) {
                    Ok(BatcherMsg::Req(r)) => {
                        if pending.is_empty() {
                            deadline = Some(Instant::now() + batcher_cfg.batch_timeout);
                        }
                        pending.push(r);
                        if pending.len() >= batcher_cfg.max_batch {
                            let _ = work_tx.send(std::mem::take(&mut pending));
                            deadline = None;
                        }
                    }
                    Ok(BatcherMsg::Shutdown) => {
                        if !pending.is_empty() {
                            let _ = work_tx.send(std::mem::take(&mut pending));
                        }
                        // Poison the pool: one empty batch per worker.
                        for _ in 0..batcher_cfg.workers.max(1) {
                            let _ = work_tx.send(Vec::new());
                        }
                        break;
                    }
                    Err(std::sync::mpsc::RecvTimeoutError::Timeout) => {
                        if !pending.is_empty() {
                            let _ = work_tx.send(std::mem::take(&mut pending));
                        }
                        deadline = None;
                    }
                    Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => break,
                }
            }
        });

        Self {
            tx,
            metrics,
            batcher: Some(batcher),
            workers,
        }
    }

    /// Submit one image; returns the response receiver.
    pub fn submit(&self, image: Vec<f32>) -> Receiver<Response> {
        let (resp_tx, resp_rx) = channel();
        let _ = self.tx.send(BatcherMsg::Req(Request {
            image,
            submitted: Instant::now(),
            resp: resp_tx,
        }));
        resp_rx
    }

    /// Drain and stop all threads.
    pub fn shutdown(mut self) -> Arc<Metrics> {
        let _ = self.tx.send(BatcherMsg::Shutdown);
        if let Some(b) = self.batcher.take() {
            let _ = b.join();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
        Arc::clone(&self.metrics)
    }
}

fn run_batch(
    cfg: &ServeConfig,
    worker_id: u64,
    weights: &TensorMap,
    tables: Option<&ErrorTables>,
    metrics: &Metrics,
    batch: Vec<Request>,
) {
    let n = batch.len();
    let img_len = 32 * 32 * 3;
    let mut images = Vec::with_capacity(n * img_len);
    for r in &batch {
        assert_eq!(r.image.len(), img_len, "bad image size");
        images.extend_from_slice(&r.image);
    }
    let result = run_images(cfg, worker_id, weights, tables, &images, n);
    let now = Instant::now();
    let classes = result.classes;
    let mut lats = Vec::with_capacity(n);
    for (i, r) in batch.into_iter().enumerate() {
        let latency = now.duration_since(r.submitted);
        lats.push(latency);
        let _ = r.resp.send(Response {
            logits: result.logits[i * classes..(i + 1) * classes].to_vec(),
            latency,
            batch_size: n,
        });
    }
    metrics.record(n, &lats, result.stats.cycles, result.stats.corrupted);
}

/// Execute `n` independent images of one batch, splitting them into
/// contiguous sub-batches across `cfg.threads` scoped workers (each with
/// its own deterministic `Executor`), and merge the results in request
/// order.
fn run_images(
    cfg: &ServeConfig,
    worker_id: u64,
    weights: &TensorMap,
    tables: Option<&ErrorTables>,
    images: &[f32],
    n: usize,
) -> ForwardResult {
    let img_len = 32 * 32 * 3;
    let run_chunk = |chunk_id: u64, imgs: &[f32], bn: usize| {
        let mut ex = Executor::new(
            weights,
            cfg.width_mult,
            cfg.precision,
            Backend::Gavina {
                arch: cfg.arch.clone(),
                tables,
                seed: cfg.seed
                    ^ worker_id.wrapping_mul(0xD1F)
                    ^ chunk_id.wrapping_mul(0x9E37_79B9),
            },
        );
        ex.layer_gs = cfg.layer_gs.clone();
        ex.forward(imgs, bn)
    };

    let threads = parallel::resolve_threads(cfg.threads);
    if threads <= 1 || n <= 1 {
        return run_chunk(0, images, n);
    }

    // Contiguous sub-batches, one per thread, merged in request order.
    let chunk = n.div_ceil(threads.min(n));
    let starts: Vec<usize> = (0..n).step_by(chunk).collect();
    let parts = parallel::parallel_map(&starts, starts.len(), |ci, &i0| {
        let bn = chunk.min(n - i0);
        run_chunk(ci as u64, &images[i0 * img_len..(i0 + bn) * img_len], bn)
    });

    let mut logits = Vec::with_capacity(n * 10);
    let mut stats = ForwardStats::default();
    let mut classes = 0;
    for part in parts {
        logits.extend_from_slice(&part.logits);
        classes = part.classes;
        stats.absorb(&part.stats);
    }
    ForwardResult {
        logits,
        n,
        classes,
        stats,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dnn::exec::synth::synthetic_weights;
    use crate::util::Prng;

    fn small_cfg() -> ServeConfig {
        ServeConfig {
            arch: ArchConfig::tiny(),
            precision: Precision::new(2, 2),
            layer_gs: vec![Precision::new(2, 2).max_g(); crate::dnn::conv_layer_names().len()],
            width_mult: 0.125,
            workers: 2,
            threads: 1,
            max_batch: 4,
            batch_timeout: Duration::from_millis(5),
            seed: 1,
        }
    }

    #[test]
    fn serves_requests_end_to_end() {
        let weights = Arc::new(synthetic_weights(0.125, 1));
        let coord = Coordinator::start(small_cfg(), Arc::clone(&weights), None);
        let mut rng = Prng::new(2);
        let mut rxs = Vec::new();
        for _ in 0..10 {
            let img: Vec<f32> = (0..32 * 32 * 3).map(|_| rng.next_f32()).collect();
            rxs.push(coord.submit(img));
        }
        for rx in rxs {
            let resp = rx.recv_timeout(Duration::from_secs(120)).expect("response");
            assert_eq!(resp.logits.len(), 10);
            assert!(resp.batch_size >= 1 && resp.batch_size <= 4);
            assert!(resp.logits.iter().all(|v| v.is_finite()));
        }
        let m = coord.shutdown();
        assert_eq!(m.requests.load(Ordering::Relaxed), 10);
        assert!(m.batches.load(Ordering::Relaxed) >= 3); // max_batch 4
        assert!(m.sim_cycles.load(Ordering::Relaxed) > 0);
        let (p50, p95, max) = m.latency_percentiles();
        assert!(p50 > 0 && p95 >= p50 && max >= p95);
    }

    #[test]
    fn batching_respects_max_batch() {
        let weights = Arc::new(synthetic_weights(0.125, 3));
        let mut cfg = small_cfg();
        cfg.max_batch = 2;
        let coord = Coordinator::start(cfg, weights, None);
        let mut rng = Prng::new(4);
        let rxs: Vec<_> = (0..6)
            .map(|_| coord.submit((0..32 * 32 * 3).map(|_| rng.next_f32()).collect()))
            .collect();
        for rx in rxs {
            let resp = rx.recv_timeout(Duration::from_secs(120)).unwrap();
            assert!(resp.batch_size <= 2);
        }
        coord.shutdown();
    }

    #[test]
    fn run_images_parallel_matches_same_partition_serial() {
        // The threaded batch executor must produce exactly the logits of
        // serially running each sub-batch with the same per-chunk seeds —
        // parallelism moves work to other threads, never changes it.
        let weights = synthetic_weights(0.125, 9);
        let mut cfg = small_cfg();
        cfg.threads = 2;
        let n = 5; // odd: chunks of 3 + 2
        let img_len = 32 * 32 * 3;
        let mut rng = Prng::new(10);
        let images: Vec<f32> = (0..n * img_len).map(|_| rng.next_f32()).collect();

        let parallel = run_images(&cfg, 0, &weights, None, &images, n);
        assert_eq!(parallel.logits.len(), n * parallel.classes);

        let chunk = n.div_ceil(cfg.threads);
        let mut expect = Vec::new();
        for (ci, i0) in (0..n).step_by(chunk).enumerate() {
            let bn = chunk.min(n - i0);
            let mut ex = Executor::new(
                &weights,
                cfg.width_mult,
                cfg.precision,
                Backend::Gavina {
                    arch: cfg.arch.clone(),
                    tables: None,
                    seed: cfg.seed ^ (ci as u64).wrapping_mul(0x9E37_79B9),
                },
            );
            ex.layer_gs = cfg.layer_gs.clone();
            let out = ex.forward(&images[i0 * img_len..(i0 + bn) * img_len], bn);
            expect.extend_from_slice(&out.logits);
        }
        assert_eq!(parallel.logits, expect);

        // And a second identical call is bit-identical (deterministic).
        let again = run_images(&cfg, 0, &weights, None, &images, n);
        assert_eq!(parallel.logits, again.logits);
        assert_eq!(parallel.stats.cycles, again.stats.cycles);
    }

    #[test]
    fn intra_batch_threads_serve_end_to_end() {
        let weights = Arc::new(synthetic_weights(0.125, 11));
        let mut cfg = small_cfg();
        cfg.threads = 2;
        cfg.max_batch = 6;
        let coord = Coordinator::start(cfg, Arc::clone(&weights), None);
        let mut rng = Prng::new(12);
        let rxs: Vec<_> = (0..9)
            .map(|_| coord.submit((0..32 * 32 * 3).map(|_| rng.next_f32()).collect()))
            .collect();
        for rx in rxs {
            let resp = rx.recv_timeout(Duration::from_secs(120)).expect("response");
            assert_eq!(resp.logits.len(), 10);
            assert!(resp.logits.iter().all(|v| v.is_finite()));
        }
        let m = coord.shutdown();
        assert_eq!(m.requests.load(Ordering::Relaxed), 9);
    }

    #[test]
    fn shutdown_flushes_pending() {
        let weights = Arc::new(synthetic_weights(0.125, 5));
        let mut cfg = small_cfg();
        cfg.max_batch = 64; // never reached
        cfg.batch_timeout = Duration::from_secs(3600); // never fires
        let coord = Coordinator::start(cfg, weights, None);
        let mut rng = Prng::new(6);
        let rx = coord.submit((0..32 * 32 * 3).map(|_| rng.next_f32()).collect());
        // Shutdown must flush the pending (sub-batch) request.
        let m_handle = std::thread::spawn(move || coord.shutdown());
        let resp = rx.recv_timeout(Duration::from_secs(120)).expect("flushed");
        assert_eq!(resp.logits.len(), 10);
        m_handle.join().unwrap();
    }
}
